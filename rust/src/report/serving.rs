//! Serving study: latency–throughput curves, autoscaling economics and
//! closed-loop capacity for UbiMoE fleets — the deployment-scale
//! figure set the paper stops short of (Tables I–III are
//! single-device, single-image).
//!
//! Three questions, three table families:
//!
//! * **Open-loop curves** ([`fleet_curve`], [`mixed_fleet_table`]):
//!   for each (platform, fleet size), sweep offered load as a fraction
//!   of fleet peak and report tail latency, utilization, padding and
//!   SLO attainment. The knee — p99 rising sharply once offered load
//!   crosses sustainable throughput — is the number capacity planning
//!   actually needs, and none of it is visible in per-batch latency.
//! * **Autoscaling** ([`autoscale_study`], [`autoscale_table`]): on
//!   bursty asymmetric-MMPP traffic, compare every static fleet size
//!   with the SLO-driven controller ([`crate::serve::autoscale`]) on
//!   *device-seconds spent vs attainment achieved* — the controller
//!   must match the smallest adequate static fleet's attainment at
//!   strictly lower cost (asserted in the tests below).
//! * **Closed-loop capacity** ([`max_users_at_slo`],
//!   [`max_users_table`]): how many think-time users a fleet carries
//!   at a 99% attainment target — the [`Workload::ClosedLoop`]
//!   companion to the open-loop knee.
//!
//! SLO conventions (see EXPERIMENTS.md §Serving): the curve tables use
//! **3× the unloaded batch-1 service latency** ([`SLO_FACTOR`]) — a
//! deliberately tight bar that degrades visibly as batches fill. The
//! autoscaling and closed-loop studies target **99% attainment**,
//! which a full largest-batch rider must be able to meet, so they use
//! **3× the largest-batch service time** ([`AUTOSCALE_SLO_FACTOR`],
//! [`attainable_slo`]).

use std::time::Duration;

use crate::models::m3vit_small;
use crate::resources::{AttnParams, LinearParams, Platform, PlatformKind};
use crate::serve::autoscale::AutoscaleConfig;
use crate::serve::device::DeviceModel;
use crate::serve::dispatch::DispatchPolicy;
use crate::serve::workload::NUM_CLASSES;
use crate::serve::{
    simulate_fleet, AdmissionConfig, BrownoutConfig, ClassMix, DriftConfig, FaultConfig,
    FaultPlan, FaultSpan, FleetReport, OverloadConfig, RebalanceConfig, ServeConfig, ShardConfig,
    Workload,
};
use crate::sim::HwChoice;
use crate::util::table::{f1, f2, Table};

/// Offered-load fractions of fleet peak swept by default: dense around
/// the knee, one point well past it.
pub const DEFAULT_UTILS: &[f64] = &[0.3, 0.5, 0.7, 0.85, 0.95, 1.05, 1.2];

/// Curve-table SLO = `SLO_FACTOR` × unloaded batch-1 latency.
pub const SLO_FACTOR: u32 = 3;

/// High-attainment SLO = `AUTOSCALE_SLO_FACTOR` × the largest-batch
/// service time (see [`attainable_slo`]).
pub const AUTOSCALE_SLO_FACTOR: u32 = 3;

/// The end-to-end SLO a deployment of `device` can defend at ≥99%
/// attainment: [`AUTOSCALE_SLO_FACTOR`] × the largest compiled batch's
/// service time. (The curve tables keep the historical tight 3×
/// batch-1 bar, under which a full largest-batch rider *starts* near
/// the budget — fine for watching attainment degrade along a curve,
/// unattainable as a 99% target.)
pub fn attainable_slo(device: &DeviceModel) -> Duration {
    let largest = *device.batch_sizes.last().expect("device with no batch sizes");
    device.service_time(largest) * AUTOSCALE_SLO_FACTOR
}

/// A pinned, Table-I-class m3vit-small demo design for `platform` —
/// the single fixture shared by `serve_smoke`, the serving tests and
/// the DES acceptance test, so smoke and tests can never silently
/// assert against different devices. No HAS cost; production paths
/// use [`DeviceModel::from_search`].
pub fn demo_device(platform: &Platform) -> DeviceModel {
    DeviceModel::with_hw(&m3vit_small(), platform, demo_hw(platform), &[1, 2, 4, 8])
}

/// The pinned [`HwChoice`] behind [`demo_device`], exposed so the fleet
/// planner ([`crate::report::plan`]) can re-cost the same design at
/// other bit-width tiers and attach a `design_power` figure to it.
pub fn demo_hw(platform: &Platform) -> HwChoice {
    match platform.kind {
        PlatformKind::AlveoU280 => HwChoice {
            num: 3,
            attn: AttnParams { t_a: 16, n_a: 16 },
            lin: LinearParams { t_in: 16, t_out: 16, n_l: 6 },
            q_bits: 16,
            a_bits: 32,
        },
        _ => HwChoice {
            num: 2,
            attn: AttnParams { t_a: 8, n_a: 8 },
            lin: LinearParams { t_in: 16, t_out: 16, n_l: 2 },
            q_bits: 16,
            a_bits: 32,
        },
    }
}

/// One point of a latency–throughput curve. (`PartialEq` backs the
/// parallel-vs-sequential equivalence test: points are produced by
/// identical deterministic computations, so exact float equality is
/// the right assertion.)
#[derive(Clone, Debug, PartialEq)]
pub struct CurvePoint {
    /// Offered load as a fraction of fleet peak throughput.
    pub util_target: f64,
    pub offered_rps: f64,
    pub achieved_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// Mean device busy fraction over the makespan.
    pub device_util: f64,
    pub padding_fraction: f64,
    pub slo_ms: f64,
    pub slo_attainment: f64,
}

/// Assemble a [`CurvePoint`] from a finished fleet run — the single
/// place report metrics are read off a [`FleetReport`], shared by the
/// homogeneous curves and the mixed-fleet table.
fn point_from_report(u: f64, r: &FleetReport, slo: Duration) -> CurvePoint {
    let [p50, p99, p999] = match r.fleet.e2e.percentiles(&[50.0, 99.0, 99.9])[..] {
        [a, b, c] => [a, b, c],
        _ => unreachable!(),
    };
    CurvePoint {
        util_target: u,
        offered_rps: r.offered_rps,
        achieved_rps: r.achieved_rps(),
        p50_ms: p50.as_secs_f64() * 1e3,
        p99_ms: p99.as_secs_f64() * 1e3,
        p999_ms: p999.as_secs_f64() * 1e3,
        device_util: r.mean_utilization(),
        padding_fraction: r.fleet.padding_fraction(),
        slo_ms: slo.as_secs_f64() * 1e3,
        slo_attainment: r.slo_attainment(slo),
    }
}

/// One point of the sweep — the shared kernel of the parallel and
/// sequential paths, so their results are identical by construction.
fn curve_point(
    device: &DeviceModel,
    n_devices: usize,
    policy: DispatchPolicy,
    num_experts: usize,
    u: f64,
    horizon: Duration,
    seed: u64,
) -> CurvePoint {
    let peak = device.peak_rps() * n_devices as f64;
    let slo = device.unloaded_latency() * SLO_FACTOR;
    let mut cfg = ServeConfig::uniform(
        device.clone(),
        n_devices,
        Workload::Poisson { rate_rps: u * peak },
    );
    cfg.dispatch = policy;
    cfg.num_experts = num_experts;
    cfg.horizon = horizon;
    cfg.seed = seed;
    point_from_report(u, &simulate_fleet(&cfg), slo)
}

/// Sweep a homogeneous fleet of `n_devices` replicas of `device` over
/// Poisson loads at `utils` × fleet peak. `num_experts` is the served
/// model's expert count (feeds the dominant-expert hint stream; 0 for
/// plain transformers). Deterministic in `seed`.
///
/// Points are independent DES runs, so they execute concurrently on
/// scoped threads (the `report::deploy_many` pattern) and return in
/// input order, bit-identical to [`fleet_curve_seq`] — enforced by an
/// equivalence test.
pub fn fleet_curve(
    device: &DeviceModel,
    n_devices: usize,
    policy: DispatchPolicy,
    num_experts: usize,
    utils: &[f64],
    horizon: Duration,
    seed: u64,
) -> Vec<CurvePoint> {
    if utils.len() <= 1 {
        return fleet_curve_seq(device, n_devices, policy, num_experts, utils, horizon, seed);
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = utils
            .iter()
            .map(|&u| {
                scope.spawn(move || {
                    curve_point(device, n_devices, policy, num_experts, u, horizon, seed)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("curve worker panicked"))
            .collect()
    })
}

/// The retained sequential sweep (reference path for the
/// parallel-equivalence test; also what single-point sweeps use).
pub fn fleet_curve_seq(
    device: &DeviceModel,
    n_devices: usize,
    policy: DispatchPolicy,
    num_experts: usize,
    utils: &[f64],
    horizon: Duration,
    seed: u64,
) -> Vec<CurvePoint> {
    utils
        .iter()
        .map(|&u| curve_point(device, n_devices, policy, num_experts, u, horizon, seed))
        .collect()
}

/// Render a curve as a report table.
pub fn curve_table(title: &str, pts: &[CurvePoint]) -> Table {
    let mut t = Table::new(
        title,
        &[
            "load/peak",
            "offered (req/s)",
            "achieved (req/s)",
            "p50 (ms)",
            "p99 (ms)",
            "p999 (ms)",
            "util",
            "padding",
            "SLO met",
        ],
    );
    for p in pts {
        t.row(&[
            f2(p.util_target),
            f1(p.offered_rps),
            f1(p.achieved_rps),
            f2(p.p50_ms),
            f2(p.p99_ms),
            f2(p.p999_ms),
            format!("{:.0}%", 100.0 * p.device_util),
            format!("{:.1}%", 100.0 * p.padding_fraction),
            format!("{:.1}%", 100.0 * p.slo_attainment),
        ]);
    }
    t
}

/// Offered-load fractions the mixed-fleet study probes: one
/// comfortable point and one near the knee, where routing quality
/// decides the tail.
pub const MIXED_FLEET_UTILS: &[f64] = &[0.6, 0.85];

/// One mixed-fleet run per util for one policy — the ROADMAP
/// "heterogeneous fleets" study kernel: a slow edge tier next to a
/// fast core tier behind one dispatcher. JSQ compares queue *lengths*
/// and keeps feeding the slow edge tier whenever its count dips below
/// the core tier's; SED keys the same tournament tree by
/// expected-completion ns from each device's own service LUT, so the
/// edge tier is used only when the core backlog genuinely costs more
/// — which is what cuts the p99 (asserted in the tests below).
///
/// `num_experts` is the served model's expert count (0 for plain
/// transformers — disables hints and the residency discount). The SLO
/// is [`SLO_FACTOR`] × the *edge* (slowest) unloaded batch-1 latency,
/// so attainment is comparable across policies and achievable on
/// either tier.
#[allow(clippy::too_many_arguments)]
pub fn mixed_fleet_points(
    edge: &DeviceModel,
    n_edge: usize,
    core: &DeviceModel,
    n_core: usize,
    policy: DispatchPolicy,
    num_experts: usize,
    utils: &[f64],
    horizon: Duration,
    seed: u64,
) -> Vec<CurvePoint> {
    let mut devices = vec![edge.clone(); n_edge];
    devices.extend((0..n_core).map(|_| core.clone()));
    let peak: f64 = devices.iter().map(|d| d.peak_rps()).sum();
    let slo = edge.unloaded_latency().max(core.unloaded_latency()) * SLO_FACTOR;
    utils
        .iter()
        .map(|&u| {
            let mut cfg = ServeConfig::mixed(
                devices.clone(),
                Workload::Poisson { rate_rps: u * peak },
            );
            cfg.dispatch = policy;
            cfg.num_experts = num_experts;
            cfg.horizon = horizon;
            cfg.seed = seed;
            point_from_report(u, &simulate_fleet(&cfg), slo)
        })
        .collect()
}

/// Render the mixed-fleet RR vs WRR vs JSQ vs SED comparison as one
/// table (a row per (load, policy)) — what `serving_study` / `ubimoe
/// serve --study` append after the homogeneous curves. WRR is the
/// static-weights baseline: admission shares proportional to each
/// device's 1/period, blind to queue state — capacity-aware routing
/// without feedback, which is exactly what SED's expected-delay signal
/// must beat (asserted in the tests below). The (util × policy) cells
/// are independent DES runs and execute on scoped threads (the
/// [`fleet_curve`] pattern); rows land in grid order.
#[allow(clippy::too_many_arguments)]
pub fn mixed_fleet_table(
    edge: &DeviceModel,
    n_edge: usize,
    core: &DeviceModel,
    n_core: usize,
    num_experts: usize,
    utils: &[f64],
    horizon: Duration,
    seed: u64,
) -> Table {
    let policies = [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::WeightedRoundRobin,
        DispatchPolicy::JoinShortestQueue,
        DispatchPolicy::ShortestExpectedDelay,
    ];
    let grid: Vec<(f64, DispatchPolicy)> = utils
        .iter()
        .flat_map(|&u| policies.into_iter().map(move |policy| (u, policy)))
        .collect();
    let points: Vec<CurvePoint> = std::thread::scope(|scope| {
        let handles: Vec<_> = grid
            .iter()
            .map(|&(u, policy)| {
                scope.spawn(move || {
                    mixed_fleet_points(
                        edge, n_edge, core, n_core, policy, num_experts, &[u], horizon, seed,
                    )
                    .remove(0)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("mixed-fleet worker panicked"))
            .collect()
    });
    let mut t = Table::new(
        &format!(
            "Serving: mixed fleet — {} x{n_edge} edge + {} x{n_core} core (RR vs JSQ vs SED)",
            edge.name, core.name
        ),
        &[
            "load/peak",
            "policy",
            "offered (req/s)",
            "achieved (req/s)",
            "p50 (ms)",
            "p99 (ms)",
            "p999 (ms)",
            "util",
            "SLO met",
        ],
    );
    for ((_, policy), p) in grid.iter().zip(points) {
        t.row(&[
            f2(p.util_target),
            policy.name().to_string(),
            f1(p.offered_rps),
            f1(p.achieved_rps),
            f2(p.p50_ms),
            f2(p.p99_ms),
            f2(p.p999_ms),
            format!("{:.0}%", 100.0 * p.device_util),
            format!("{:.1}%", 100.0 * p.slo_attainment),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Autoscaling study.

/// Calm-state rate of the autoscaling scenario, × one device's peak.
pub const AUTOSCALE_CALM_FRACTION: f64 = 0.25;
/// Burst-state rate of the autoscaling scenario, × one device's peak.
pub const AUTOSCALE_BURST_FRACTION: f64 = 2.6;

/// One run of the autoscaling comparison (a static fleet or the
/// controller).
#[derive(Clone, Debug)]
pub struct AutoscaleRow {
    /// "static-N" or "autoscaler".
    pub label: String,
    /// Largest serving fleet over the run (= N for statics).
    pub peak_devices: usize,
    /// Whole-run SLO attainment at the study SLO.
    pub attainment: f64,
    pub p99_ms: f64,
    pub achieved_rps: f64,
    /// Integrated availability ([`FleetReport::device_seconds`]).
    pub device_seconds: f64,
    /// attainment ≥ the study target.
    pub meets: bool,
}

/// Result of [`autoscale_study`]: every static fleet size and the
/// controller on identical traffic.
#[derive(Clone, Debug)]
pub struct AutoscaleStudy {
    pub slo: Duration,
    pub target_attainment: f64,
    /// static-1..=static-N ascending, controller last.
    pub rows: Vec<AutoscaleRow>,
}

impl AutoscaleStudy {
    /// The controller's row (always present, always last).
    pub fn controller(&self) -> &AutoscaleRow {
        self.rows.last().expect("study rows cannot be empty")
    }

    /// The smallest static fleet meeting the attainment target.
    pub fn smallest_static_meeting(&self) -> Option<&AutoscaleRow> {
        self.rows[..self.rows.len() - 1].iter().find(|r| r.meets)
    }

    /// Device-seconds the controller saves vs the smallest adequate
    /// static fleet, as a fraction of the latter (`None` when no
    /// static fleet meets the target).
    pub fn saving_fraction(&self) -> Option<f64> {
        self.smallest_static_meeting()
            .map(|s| 1.0 - self.controller().device_seconds / s.device_seconds)
    }
}

fn autoscale_row(label: String, r: &FleetReport, slo: Duration, target: f64) -> AutoscaleRow {
    let attainment = r.slo_attainment(slo);
    AutoscaleRow {
        label,
        peak_devices: r.autoscale.as_ref().map_or(r.per_device.len(), |s| s.peak_active),
        attainment,
        p99_ms: r.fleet.e2e.p99().as_secs_f64() * 1e3,
        achieved_rps: r.achieved_rps(),
        device_seconds: r.device_seconds,
        meets: attainment >= target,
    }
}

/// The autoscaling economics study (the ROADMAP "close the loop"
/// item): identical bursty traffic — an asymmetric MMPP dwelling
/// calm ([`AUTOSCALE_CALM_FRACTION`] × one device's peak, mean dwell
/// horizon/4) with rare hard bursts ([`AUTOSCALE_BURST_FRACTION`] ×
/// peak, mean dwell horizon/16) — served by every static fleet of
/// 1..=`max_static` replicas and by the SLO-driven controller
/// (starting from one replica; its ceiling is the capacity plan
/// ceil(burst / ρ-target) — provisioning a device the burst ceiling
/// can never use would only burn device-seconds). The SLO is
/// [`attainable_slo`]`(device)` with a 99% attainment target.
///
/// The shape this produces: small static fleets blow the SLO during
/// bursts, the burst-sized static fleet meets it but idles through
/// every calm phase, and the controller matches the latter's
/// attainment while paying for burst capacity only while bursts last —
/// strictly fewer device-seconds (asserted in the tests and printed by
/// `ubimoe serve --study`). Static runs execute concurrently on scoped
/// threads; everything is deterministic in `seed`.
pub fn autoscale_study(
    device: &DeviceModel,
    max_static: usize,
    horizon: Duration,
    seed: u64,
) -> AutoscaleStudy {
    assert!(max_static >= 1);
    let peak = device.peak_rps();
    let slo = attainable_slo(device);
    let target = 0.99;
    let workload = Workload::Mmpp2 {
        rate_low_rps: AUTOSCALE_CALM_FRACTION * peak,
        rate_high_rps: AUTOSCALE_BURST_FRACTION * peak,
        dwell_low: horizon / 4,
        dwell_high: horizon / 16,
    };
    let run = |n: usize, autoscale: Option<AutoscaleConfig>| -> FleetReport {
        let mut cfg = ServeConfig::uniform(device.clone(), n, workload.clone());
        cfg.horizon = horizon;
        cfg.seed = seed;
        cfg.autoscale = autoscale;
        simulate_fleet(&cfg)
    };
    let mut ac = AutoscaleConfig::for_device(device.clone(), slo);
    ac.target_attainment = target;
    ac.min_devices = 1;
    ac.max_devices = ((AUTOSCALE_BURST_FRACTION / ac.rho_target).ceil() as usize)
        .min(max_static)
        .max(1);
    // Every run — the statics and the controller — is an independent
    // DES over the same schedule: one scope, fully concurrent, rows in
    // fixed order (statics ascending, controller last).
    let rows: Vec<AutoscaleRow> = std::thread::scope(|scope| {
        let run = &run;
        let mut handles: Vec<_> = (1..=max_static)
            .map(|n| {
                scope.spawn(move || {
                    autoscale_row(format!("static-{n}"), &run(n, None), slo, target)
                })
            })
            .collect();
        handles.push(scope.spawn(move || {
            autoscale_row("autoscaler".into(), &run(1, Some(ac)), slo, target)
        }));
        handles
            .into_iter()
            .map(|h| h.join().expect("autoscale study worker panicked"))
            .collect()
    });
    AutoscaleStudy { slo, target_attainment: target, rows }
}

/// Render an [`AutoscaleStudy`] (one row per run, plus a saving row
/// when the controller beats an adequate static fleet).
pub fn autoscale_table(study: &AutoscaleStudy) -> Table {
    let mut t = Table::new(
        &format!(
            "Serving: SLO-driven autoscaling vs static fleets — bursty MMPP \
             (SLO {:.1} ms e2e, target {:.0}% attainment)",
            study.slo.as_secs_f64() * 1e3,
            100.0 * study.target_attainment
        ),
        &[
            "fleet",
            "peak devices",
            "SLO attainment",
            "p99 (ms)",
            "achieved (req/s)",
            "device-seconds",
            "meets target",
        ],
    );
    for r in &study.rows {
        t.row(&[
            r.label.clone(),
            r.peak_devices.to_string(),
            format!("{:.2}%", 100.0 * r.attainment),
            f2(r.p99_ms),
            f1(r.achieved_rps),
            f1(r.device_seconds),
            (if r.meets { "yes" } else { "NO" }).to_string(),
        ]);
    }
    if let (true, Some(saving), Some(s)) = (
        study.controller().meets,
        study.saving_fraction(),
        study.smallest_static_meeting(),
    ) {
        t.row(&[
            format!("autoscaler saving vs {}", s.label),
            "—".into(),
            "—".into(),
            "—".into(),
            "—".into(),
            format!("{:.1}%", 100.0 * saving),
            "—".into(),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Chaos / fault-tolerance study.

/// Offered load of the chaos outage scenario, × fleet peak — ρ = 0.6
/// leaves the surviving third of the fleet overloaded (1.8× its peak)
/// while two of three devices are down.
pub const CHAOS_UTIL: f64 = 0.6;
/// Offered load of the chaos availability scenario, × fleet peak —
/// ρ = 0.65 puts the two survivors of a single-device outage at 0.975×
/// their joint peak, deep enough into the knee that the SLO visibly
/// craters without replacement capacity.
pub const CHAOS_AVAIL_UTIL: f64 = 0.65;

/// One run of the chaos comparison.
#[derive(Clone, Debug)]
pub struct ChaosRow {
    /// e.g. "jsq+retry", "jsq no-retry", "jsq autoscaled (long outage)".
    pub label: String,
    /// completed / admitted.
    pub goodput: f64,
    pub dropped: u64,
    pub retries: u64,
    /// Request copies re-dispatched off failed devices.
    pub failovers: u64,
    pub hedges: u64,
    pub hedge_wins: u64,
    /// Mean per-slot availability over the run.
    pub availability: f64,
    pub p99_ms: f64,
    /// SLO attainment over *admitted* requests (drops count as
    /// misses), at the study SLO.
    pub attainment: f64,
    pub device_seconds: f64,
}

/// Result of [`chaos_study`]: dispatch policies under a two-device
/// outage with retry/hedge machinery, a no-retry baseline, and a
/// static-vs-autoscaled pair under a long single-device outage — all
/// on one device template.
#[derive(Clone, Debug)]
pub struct ChaosStudy {
    /// Study SLO: 2× the largest-batch service time — tight enough
    /// that losing a third of the fleet at ρ = 0.65 visibly misses it.
    pub slo: Duration,
    pub rows: Vec<ChaosRow>,
}

impl ChaosStudy {
    pub fn row(&self, label: &str) -> &ChaosRow {
        self.rows
            .iter()
            .find(|r| r.label == label)
            .unwrap_or_else(|| panic!("no chaos row labeled {label:?}"))
    }
}

fn chaos_row(label: String, r: &FleetReport, slo: Duration) -> ChaosRow {
    let end = r.makespan.max(r.horizon);
    let fs = r.faults.as_ref();
    ChaosRow {
        label,
        goodput: r.goodput_fraction(),
        dropped: r.dropped,
        retries: fs.map_or(0, |f| f.retries),
        failovers: fs.map_or(0, |f| f.failovers),
        hedges: fs.map_or(0, |f| f.hedges),
        hedge_wins: fs.map_or(0, |f| f.hedge_wins),
        availability: fs.map_or(1.0, |f| f.mean_availability(end)),
        p99_ms: r.fleet.e2e.p99().as_secs_f64() * 1e3,
        attainment: r.slo_attainment_admitted(slo),
        device_seconds: r.device_seconds,
    }
}

/// The fault-tolerance study (the chaos companion to
/// [`autoscale_study`]): one 3-replica fleet of `device`, two
/// calibrated fault loads, every mechanism the DES has.
///
/// **Outage scenario** (rows 1–6): Poisson at [`CHAOS_UTIL`] × fleet
/// peak; devices 0 *and* 1 scripted down for 12 largest-batch service
/// times starting at horizon/3 — two thirds of the fleet gone under
/// real load. Per-attempt deadline 6× the largest-batch service time,
/// 4-attempt budget, capped exponential backoff. Compared across
/// RR / JSQ / SED / expert-affinity dispatch, plus a JSQ run with
/// hedging on top and a JSQ **no-retry** baseline (attempt budget 1):
/// the baseline drops every request the outage strands, the retry
/// rows keep goodput ≥ 95% of offered (asserted in the tests).
///
/// **Availability scenario** (last two rows): Poisson at
/// [`CHAOS_AVAIL_UTIL`] × fleet peak; device 0 down from horizon/3 to
/// horizon·5/6. No deadline — nothing drops; the capacity loss shows
/// up purely as SLO attainment. The static fleet eats it; the
/// autoscaled fleet spawns a replacement at the next controller tick
/// and restores the SLO without operator input (asserted).
///
/// `num_experts` feeds the hint stream (0 disables residency effects —
/// the calibrated configuration the test margins were measured at).
/// Rows are independent DES runs on scoped threads; deterministic in
/// `seed`.
pub fn chaos_study(
    device: &DeviceModel,
    num_experts: usize,
    horizon: Duration,
    seed: u64,
) -> ChaosStudy {
    let n = 3usize;
    let peak = device.peak_rps() * n as f64;
    let largest = *device.batch_sizes.last().expect("device with no batch sizes");
    let svc_l = device.service_time(largest);
    let slo = svc_l * 2;
    let outage_from = horizon / 3;
    let outage = FaultPlan::new(vec![
        FaultSpan::new(0, outage_from, outage_from + svc_l * 12),
        FaultSpan::new(1, outage_from, outage_from + svc_l * 12),
    ]);
    let retry_faults = |max_attempts: u32, hedge: Option<Duration>| FaultConfig {
        plan: outage.clone(),
        deadline: Some(svc_l * 6),
        max_attempts,
        backoff_base: svc_l,
        backoff_cap: svc_l * 4,
        hedge_delay: hedge,
        ..FaultConfig::none()
    };
    let outage_run = |policy: DispatchPolicy, faults: FaultConfig| -> FleetReport {
        let mut cfg = ServeConfig::uniform(
            device.clone(),
            n,
            Workload::Poisson { rate_rps: CHAOS_UTIL * peak },
        );
        cfg.dispatch = policy;
        cfg.num_experts = num_experts;
        cfg.horizon = horizon;
        cfg.seed = seed;
        cfg.faults = Some(faults);
        simulate_fleet(&cfg)
    };
    // Availability scenario: one device out for half the run, no
    // deadline — the hit lands on latency, not on goodput.
    let long_outage = FaultConfig {
        plan: FaultPlan::new(vec![FaultSpan::new(0, outage_from, horizon * 5 / 6)]),
        ..FaultConfig::none()
    };
    let avail_run = |autoscale: Option<AutoscaleConfig>| -> FleetReport {
        let mut cfg = ServeConfig::uniform(
            device.clone(),
            n,
            Workload::Poisson { rate_rps: CHAOS_AVAIL_UTIL * peak },
        );
        cfg.num_experts = num_experts;
        cfg.horizon = horizon;
        cfg.seed = seed;
        cfg.faults = Some(long_outage.clone());
        cfg.autoscale = autoscale;
        simulate_fleet(&cfg)
    };
    let policies = [
        DispatchPolicy::RoundRobin,
        DispatchPolicy::JoinShortestQueue,
        DispatchPolicy::ShortestExpectedDelay,
        DispatchPolicy::ExpertAffinity,
    ];
    let rows: Vec<ChaosRow> = std::thread::scope(|scope| {
        let outage_run = &outage_run;
        let avail_run = &avail_run;
        let retry_faults = &retry_faults;
        let mut handles: Vec<_> = policies
            .into_iter()
            .map(|policy| {
                scope.spawn(move || {
                    chaos_row(
                        format!("{}+retry", policy.name()),
                        &outage_run(policy, retry_faults(4, None)),
                        slo,
                    )
                })
            })
            .collect();
        handles.push(scope.spawn(move || {
            chaos_row(
                "jsq+retry+hedge".into(),
                &outage_run(
                    DispatchPolicy::JoinShortestQueue,
                    retry_faults(4, Some(svc_l * 2)),
                ),
                slo,
            )
        }));
        handles.push(scope.spawn(move || {
            chaos_row(
                "jsq no-retry".into(),
                &outage_run(DispatchPolicy::JoinShortestQueue, retry_faults(1, None)),
                slo,
            )
        }));
        handles.push(scope.spawn(move || {
            chaos_row("jsq static (long outage)".into(), &avail_run(None), slo)
        }));
        handles.push(scope.spawn(move || {
            chaos_row(
                "jsq autoscaled (long outage)".into(),
                &avail_run(Some(AutoscaleConfig::for_device(device.clone(), slo))),
                slo,
            )
        }));
        handles
            .into_iter()
            .map(|h| h.join().expect("chaos study worker panicked"))
            .collect()
    });
    ChaosStudy { slo, rows }
}

/// Render a [`ChaosStudy`] as a report table.
pub fn chaos_table(study: &ChaosStudy) -> Table {
    let mut t = Table::new(
        format!(
            "Serving: chaos — failover, retries, hedging, autoscaled repair \
             (SLO {:.1} ms e2e over admitted)",
            study.slo.as_secs_f64() * 1e3
        ),
        &[
            "fleet/policy",
            "goodput",
            "dropped",
            "retries",
            "failovers",
            "hedges (won)",
            "avail",
            "p99 (ms)",
            "SLO met",
            "device-seconds",
        ],
    );
    for r in &study.rows {
        t.row(&[
            r.label.clone(),
            format!("{:.2}%", 100.0 * r.goodput),
            r.dropped.to_string(),
            r.retries.to_string(),
            r.failovers.to_string(),
            format!("{} ({})", r.hedges, r.hedge_wins),
            format!("{:.1}%", 100.0 * r.availability),
            f2(r.p99_ms),
            format!("{:.1}%", 100.0 * r.attainment),
            f1(r.device_seconds),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Overload protection.

/// Offered load of the overload study: 1.5× fleet peak — far past the
/// knee, where an unprotected open-loop fleet queues without bound.
pub const OVERLOAD_UTIL: f64 = 1.5;

/// One run of the overload comparison.
#[derive(Clone, Debug)]
pub struct OverloadRow {
    /// "unprotected (shadow)" | "admission+shedding" | "+brownout".
    pub label: String,
    /// Requests offered by the workload.
    pub offered: u64,
    /// Requests shed at the admission edge.
    pub rejected: u64,
    /// Per-class SLO attainment on the *offered* basis (a reject is a
    /// miss), indexed by priority (0 = interactive).
    pub class_attainment: [f64; NUM_CLASSES],
    /// Interactive-class p99 over completions, ms.
    pub interactive_p99_ms: f64,
    /// completed / offered.
    pub goodput: f64,
    /// Windows the fleet spent degraded (brownout duty cycle).
    pub brownout_windows: u64,
    /// Completions served on the degraded table.
    pub degraded_completions: u64,
    /// Σ accuracy-proxy cost of those completions.
    pub accuracy_cost: f64,
}

/// Result of [`overload_study`]: the same overloaded fleet under no
/// protection (shadow classification only), admission + priority
/// shedding, and shedding + brownout.
#[derive(Clone, Debug)]
pub struct OverloadStudy {
    /// Study SLO: [`attainable_slo`] (3× the largest-batch service).
    pub slo: Duration,
    pub rows: Vec<OverloadRow>,
}

impl OverloadStudy {
    pub fn row(&self, label: &str) -> &OverloadRow {
        self.rows
            .iter()
            .find(|r| r.label == label)
            .unwrap_or_else(|| panic!("no overload row labeled {label:?}"))
    }
}

fn overload_row(label: String, r: &FleetReport, slo: Duration) -> OverloadRow {
    let ov = r.overload.as_ref().expect("overload study runs carry a summary");
    let mut class_attainment = [0.0; NUM_CLASSES];
    for (c, a) in class_attainment.iter_mut().enumerate() {
        *a = ov.class_attainment_offered(c, slo);
    }
    OverloadRow {
        label,
        offered: r.admitted,
        rejected: r.rejected,
        class_attainment,
        interactive_p99_ms: ov.e2e_by_class[0].p99().as_secs_f64() * 1e3,
        goodput: r.goodput_fraction(),
        brownout_windows: ov.brownout_windows,
        degraded_completions: ov.degraded_completions,
        accuracy_cost: ov.accuracy_cost,
    }
}

/// The overload-protection study (the demand-failure companion to
/// [`chaos_study`]): one 3-replica fleet of `device`, Poisson at
/// [`OVERLOAD_UTIL`] × fleet peak under the standard 0.5/0.3/0.2
/// class mix, three protection levels:
///
/// 1. **unprotected (shadow)** — classification and per-class
///    accounting only. Queues grow without bound for the whole
///    horizon, so *every* class misses the SLO together.
/// 2. **admission+shedding** — priority-tiered resident limits
///    ([`crate::serve::AdmissionConfig::tiered`]): background is shed
///    first, and the bounded interactive queue holds class-0
///    attainment ≥ 99% on the offered basis (asserted in the tests).
/// 3. **+brownout** — the same admission plus the hysteresis brownout
///    controller swapping devices onto a 3/5-bit-width degraded table
///    ([`crate::serve::device::DeviceModel::degraded`]) under
///    sustained windowed SLO miss (rejects count as misses). The
///    faster table absorbs load that admission alone had to shed:
///    strictly fewer rejections at equal-or-better class-0 attainment,
///    paid for in the accuracy-proxy column (asserted).
///
/// Rows are independent DES runs on scoped threads; deterministic in
/// `seed`.
pub fn overload_study(
    device: &DeviceModel,
    num_experts: usize,
    horizon: Duration,
    seed: u64,
) -> OverloadStudy {
    let n = 3usize;
    let peak = device.peak_rps() * n as f64;
    let largest = *device.batch_sizes.last().expect("device with no batch sizes");
    let svc_l = device.service_time(largest);
    let slo = attainable_slo(device);
    let run = |overload: OverloadConfig| -> FleetReport {
        let mut cfg = ServeConfig::uniform(
            device.clone(),
            n,
            Workload::Poisson { rate_rps: OVERLOAD_UTIL * peak },
        );
        cfg.num_experts = num_experts;
        cfg.horizon = horizon;
        cfg.seed = seed;
        cfg.overload = Some(overload);
        simulate_fleet(&cfg)
    };
    let shed = OverloadConfig {
        mix: ClassMix::standard(),
        shadow: false,
        admission: Some(AdmissionConfig::tiered(n * largest)),
        breaker: None,
        brownout: None,
    };
    let brown = OverloadConfig {
        brownout: Some(BrownoutConfig {
            window: svc_l,
            slo,
            enter_attainment: 0.9,
            exit_attainment: 0.98,
            enter_patience: 2,
            exit_patience: 6,
            degraded: vec![device.degraded(3, 5); n],
            accuracy_cost_per_request: 0.01,
        }),
        ..shed.clone()
    };
    let rows: Vec<OverloadRow> = std::thread::scope(|scope| {
        let run = &run;
        let handles = [
            scope.spawn(move || {
                overload_row(
                    "unprotected (shadow)".into(),
                    &run(OverloadConfig::shadow(ClassMix::standard())),
                    slo,
                )
            }),
            scope.spawn({
                let shed = shed.clone();
                move || overload_row("admission+shedding".into(), &run(shed), slo)
            }),
            scope.spawn(move || overload_row("+brownout".into(), &run(brown), slo)),
        ];
        handles
            .into_iter()
            .map(|h| h.join().expect("overload study worker panicked"))
            .collect()
    });
    OverloadStudy { slo, rows }
}

/// Render an [`OverloadStudy`] as a report table.
pub fn overload_table(study: &OverloadStudy) -> Table {
    let mut t = Table::new(
        format!(
            "Serving: overload — admission, priority shedding, brownout at \
             {OVERLOAD_UTIL}x fleet peak (SLO {:.1} ms e2e over offered)",
            study.slo.as_secs_f64() * 1e3
        ),
        &[
            "protection",
            "offered",
            "rejected",
            "SLO int",
            "SLO batch",
            "SLO bg",
            "int p99 (ms)",
            "goodput",
            "degraded done",
            "acc. cost",
        ],
    );
    for r in &study.rows {
        t.row(&[
            r.label.clone(),
            r.offered.to_string(),
            r.rejected.to_string(),
            format!("{:.1}%", 100.0 * r.class_attainment[0]),
            format!("{:.1}%", 100.0 * r.class_attainment[1]),
            format!("{:.1}%", 100.0 * r.class_attainment[2]),
            f2(r.interactive_p99_ms),
            format!("{:.2}%", 100.0 * r.goodput),
            r.degraded_completions.to_string(),
            f2(r.accuracy_cost),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Expert sharding: replication, failover, drift.

/// One run of the expert-sharding comparison.
#[derive(Clone, Debug)]
pub struct ShardRow {
    /// "rf=1 outage" | "rf=2 outage" | "static drift" | "rebalanced drift".
    pub label: String,
    /// Requests admitted (== routed: every arrival is routed before the
    /// admission edge).
    pub offered: u64,
    /// completed / admitted.
    pub goodput: f64,
    /// All drops (chaos + no-replica).
    pub dropped: u64,
    /// Drops because no live device hosted any routed expert.
    pub no_replica_drops: u64,
    /// Requests served by a secondary after the primary hit capacity.
    pub rerouted: u64,
    /// Non-local expert transfers charged to completions.
    pub transfers: u64,
    /// Replicas grown by the rebalancer.
    pub replica_adds: u64,
    /// Rebalance ticks that moved at least one replica.
    pub rebalances: u64,
    /// End-to-end p99 over completions, ms.
    pub p99_ms: f64,
}

/// Result of [`shard_study`]: failover under a hot-expert home-device
/// outage (RF=1 vs RF=2) and popularity drift (static placement vs the
/// rebalancing controller).
#[derive(Clone, Debug)]
pub struct ShardStudy {
    pub rows: Vec<ShardRow>,
}

impl ShardStudy {
    pub fn row(&self, label: &str) -> &ShardRow {
        self.rows
            .iter()
            .find(|r| r.label == label)
            .unwrap_or_else(|| panic!("no shard row labeled {label:?}"))
    }
}

fn shard_row(label: String, r: &FleetReport) -> ShardRow {
    let ss = r.shard.as_ref().expect("shard study runs carry a summary");
    ShardRow {
        label,
        offered: r.admitted,
        goodput: r.goodput_fraction(),
        dropped: r.dropped,
        no_replica_drops: ss.no_replica_drops,
        rerouted: ss.rerouted,
        transfers: ss.transfers,
        replica_adds: ss.replica_adds,
        rebalances: ss.rebalances,
        p99_ms: r.fleet.e2e.p99().as_secs_f64() * 1e3,
    }
}

/// The expert-sharding study: two scenarios, four independent DES runs
/// on scoped threads, deterministic in `seed`.
///
/// **Outage** (rows "rf=1 outage" / "rf=2 outage"): 8 replicas of
/// `device`, 8 experts, top-1 routing under Zipf(1.0), Poisson at 0.5×
/// fleet peak, the hottest expert's home device dead for the middle
/// third of the run. With RF=1 every request routed to the hot expert
/// during the outage has nowhere to go and drops as `no_replica`; with
/// RF=2 (hot expert replicated) the second copy absorbs the outage and
/// goodput stays ≥ 95% (asserted in the tests against the RF=1 run).
///
/// **Drift** (rows "static drift" / "rebalanced drift"): 4 replicas,
/// 8 experts, Zipf(2.0) — the hot expert alone exceeds one device's
/// peak — with the rank→expert mapping shifting every sixth of the
/// horizon. Static placement leaves each drifted hot expert on a
/// single cold-start device; the rebalancing controller re-replicates
/// the current top-2 every 1/30 horizon and holds p99 to less than
/// half of static's (asserted).
pub fn shard_study(device: &DeviceModel, horizon: Duration, seed: u64) -> ShardStudy {
    let num_experts = 8usize;
    let outage = |replication: usize| -> FleetReport {
        let n = 8usize;
        let mut cfg = ServeConfig::uniform(
            device.clone(),
            n,
            Workload::Poisson { rate_rps: 0.5 * device.peak_rps() * n as f64 },
        );
        cfg.num_experts = num_experts;
        cfg.horizon = horizon;
        cfg.seed = seed;
        cfg.shard = Some(ShardConfig {
            replication,
            hot_experts: 1,
            ..ShardConfig::plain(1, 1.0)
        });
        cfg.faults = Some(FaultConfig {
            plan: FaultPlan::new(vec![FaultSpan::new(0, horizon / 3, horizon * 2 / 3)]),
            ..FaultConfig::none()
        });
        simulate_fleet(&cfg)
    };
    let drift = |rebalance: bool| -> FleetReport {
        let n = 4usize;
        let mut cfg = ServeConfig::uniform(
            device.clone(),
            n,
            Workload::Poisson { rate_rps: 0.5 * device.peak_rps() * n as f64 },
        );
        cfg.num_experts = num_experts;
        cfg.horizon = horizon;
        cfg.seed = seed;
        cfg.shard = Some(ShardConfig {
            replication: 2,
            hot_experts: 2,
            drift: Some(DriftConfig { every: horizon / 6, shift: 1 }),
            rebalance: rebalance.then(|| RebalanceConfig { every: horizon / 30 }),
            ..ShardConfig::plain(1, 2.0)
        });
        simulate_fleet(&cfg)
    };
    let rows: Vec<ShardRow> = std::thread::scope(|scope| {
        let outage = &outage;
        let drift = &drift;
        let handles = [
            scope.spawn(move || shard_row("rf=1 outage".into(), &outage(1))),
            scope.spawn(move || shard_row("rf=2 outage".into(), &outage(2))),
            scope.spawn(move || shard_row("static drift".into(), &drift(false))),
            scope.spawn(move || shard_row("rebalanced drift".into(), &drift(true))),
        ];
        handles
            .into_iter()
            .map(|h| h.join().expect("shard study worker panicked"))
            .collect()
    });
    ShardStudy { rows }
}

/// Render a [`ShardStudy`] as a report table.
pub fn shard_table(study: &ShardStudy) -> Table {
    let mut t = Table::new(
        "Serving: expert sharding — replication vs outage, rebalancing vs drift \
         (top-1 Zipf routing at 0.5x fleet peak)",
        &[
            "scenario",
            "offered",
            "goodput",
            "dropped",
            "no-replica",
            "rerouted",
            "transfers",
            "replica adds",
            "rebalances",
            "p99 (ms)",
        ],
    );
    for r in &study.rows {
        t.row(&[
            r.label.clone(),
            r.offered.to_string(),
            format!("{:.2}%", 100.0 * r.goodput),
            r.dropped.to_string(),
            r.no_replica_drops.to_string(),
            r.rerouted.to_string(),
            r.transfers.to_string(),
            r.replica_adds.to_string(),
            r.rebalances.to_string(),
            f2(r.p99_ms),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Closed-loop capacity.

/// The largest closed-loop user population a fleet of `n_devices`
/// replicas of `device` carries at ≥ `target_attainment` of the
/// [`attainable_slo`] — found by exponential probing then binary
/// search over [`Workload::ClosedLoop`] DES runs (each probe is one
/// deterministic run at `seed`). Returns the population and its
/// [`CurvePoint`] (util_target = achieved load / fleet peak).
///
/// Attainment is not perfectly monotone in the population (finite-run
/// noise), so the result is a boundary estimate, not a proof — the
/// returned point itself always meets the target (or the population is
/// 0 when even one user misses it, which only happens when a lone
/// request's service already exceeds the SLO). Probing is capped at 4×
/// the Little's-law ceiling `fleet peak × (think + SLO)`: beyond it,
/// extra users can only deepen the queue.
pub fn max_users_at_slo(
    device: &DeviceModel,
    n_devices: usize,
    think_time: Duration,
    target_attainment: f64,
    horizon: Duration,
    seed: u64,
) -> (usize, CurvePoint) {
    let slo = attainable_slo(device);
    let fleet_peak = device.peak_rps() * n_devices as f64;
    let probe = |users: usize| -> CurvePoint {
        let mut cfg = ServeConfig::uniform(
            device.clone(),
            n_devices,
            Workload::ClosedLoop { users, think_time },
        );
        cfg.horizon = horizon;
        cfg.seed = seed;
        let r = simulate_fleet(&cfg);
        point_from_report(r.achieved_rps() / fleet_peak, &r, slo)
    };
    let mut best_users = 1usize;
    let mut best = probe(1);
    if best.slo_attainment < target_attainment {
        return (0, best);
    }
    let cycle = (think_time + slo).as_secs_f64();
    let cap = ((fleet_peak * cycle).ceil() as usize).saturating_mul(4).max(16);
    let mut hi = 2usize;
    let mut first_fail = None;
    while hi <= cap {
        let p = probe(hi);
        if p.slo_attainment >= target_attainment {
            best_users = hi;
            best = p;
            hi *= 2;
        } else {
            first_fail = Some(hi);
            break;
        }
    }
    if let Some(mut bad) = first_fail {
        while bad - best_users > 1 {
            let mid = best_users + (bad - best_users) / 2;
            let p = probe(mid);
            if p.slo_attainment >= target_attainment {
                best_users = mid;
                best = p;
            } else {
                bad = mid;
            }
        }
    }
    (best_users, best)
}

/// "Max users at SLO" rows for a set of labeled devices, each as an
/// `n_devices`-replica fleet with think time 20× its batch-1 latency —
/// the closed-loop companion the open-loop knee tables cannot answer.
pub fn max_users_table(
    entries: &[(&str, &DeviceModel)],
    n_devices: usize,
    horizon: Duration,
    seed: u64,
) -> Table {
    let mut t = Table::new(
        "Serving: closed-loop max users at SLO (99% attainment, think = 20x b1)",
        &[
            "fleet",
            "SLO (ms)",
            "max users",
            "attainment",
            "p99 (ms)",
            "achieved (req/s)",
            "load/peak",
        ],
    );
    for (label, device) in entries {
        let think = device.unloaded_latency() * 20;
        let (users, p) =
            max_users_at_slo(device, n_devices, think, 0.99, horizon, seed);
        t.row(&[
            format!("{label} x{n_devices}"),
            f2(attainable_slo(device).as_secs_f64() * 1e3),
            users.to_string(),
            format!("{:.2}%", 100.0 * p.slo_attainment),
            f2(p.p99_ms),
            f1(p.achieved_rps),
            f2(p.util_target),
        ]);
    }
    t
}

/// The full serving figure set: HAS-chosen designs for m3vit-small on
/// ZCU102 and U280 (through the persistent design cache — a warm
/// process pays zero GA evaluations and zero cycle sims here), fleets
/// of `fleet_sizes` devices, each swept over [`DEFAULT_UTILS`], plus
/// the mixed-fleet policy table, the autoscaling-vs-static economics
/// table, the chaos/fault-tolerance table and the closed-loop
/// max-users table.
///
/// Parallelism: the per-platform HAS searches (the expensive part)
/// run concurrently on scoped threads, and every curve's util points
/// fan out inside [`fleet_curve`] — so the whole platform × fleet ×
/// util grid is concurrent while the output order stays fixed.
pub fn serving_study(fleet_sizes: &[usize], horizon: Duration) -> Vec<Table> {
    let model = m3vit_small();
    let platforms = [Platform::zcu102(), Platform::u280()];
    let devices: Vec<DeviceModel> = std::thread::scope(|scope| {
        let handles: Vec<_> = platforms
            .iter()
            .map(|platform| {
                let model = &model;
                scope.spawn(move || {
                    DeviceModel::from_search(model, platform, 16, 32, &[1, 2, 4, 8])
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("search worker panicked"))
            .collect()
    });
    let mut out = Vec::new();
    for (platform, device) in platforms.iter().zip(&devices) {
        for &n in fleet_sizes {
            let pts = fleet_curve(
                device,
                n,
                DispatchPolicy::JoinShortestQueue,
                model.num_experts,
                DEFAULT_UTILS,
                horizon,
                0xF1EE7,
            );
            let title = format!(
                "Serving: {} x{n} fleet, {} (b1 {:.2} ms, peak {:.1} req/s/device)",
                platform.name,
                model.name,
                device.unloaded_latency().as_secs_f64() * 1e3,
                device.peak_rps(),
            );
            out.push(curve_table(&title, &pts));
        }
    }
    // Mixed-fleet policy table on the same searched designs (no extra
    // search: devices[0] is the ZCU102 edge design, devices[1] the
    // U280 core design).
    out.push(mixed_fleet_table(
        &devices[0],
        4,
        &devices[1],
        2,
        model.num_experts,
        MIXED_FLEET_UTILS,
        horizon,
        0xF1EE7,
    ));
    // Autoscaling economics on the ZCU102 design (the edge tier is
    // where fleet sizing matters most). Bursts need a horizon an
    // order of magnitude above the curve sweeps' to show up rarely
    // (dwell_high = autoscale-horizon/16), hence ×12.
    out.push(autoscale_table(&autoscale_study(&devices[0], 5, horizon * 12, 0xF1EE7)));
    // Chaos study on the ZCU102 design: calibrated outages scale with
    // the device's service times, so the scenario shape (and the
    // graceful-degradation story) carries over from the synthetic
    // calibration fleet. ×3 the sweep horizon so the long outage spans
    // whole controller windows.
    out.push(chaos_table(&chaos_study(&devices[0], model.num_experts, horizon * 3, 0xF1EE7)));
    // Overload protection on the same design and horizon: what the
    // fleet does when demand, not hardware, is the thing that fails.
    out.push(overload_table(&overload_study(
        &devices[0],
        model.num_experts,
        horizon * 3,
        0xF1EE7,
    )));
    // Expert sharding on the same design and horizon: replication vs a
    // hot-expert home-device outage, rebalancing vs popularity drift.
    out.push(shard_table(&shard_study(&devices[0], horizon * 3, 0xF1EE7)));
    // Closed-loop capacity of both platforms' 4-device fleets.
    out.push(max_users_table(
        &[("zcu102", &devices[0]), ("u280", &devices[1])],
        4,
        horizon,
        0xF1EE7,
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn u280_device() -> DeviceModel {
        demo_device(&Platform::u280())
    }

    #[test]
    fn curve_shows_saturation_knee() {
        let pts = fleet_curve(
            &u280_device(),
            4,
            DispatchPolicy::JoinShortestQueue,
            16,
            &[0.4, 0.8, 1.15],
            Duration::from_secs(8),
            7,
        );
        assert_eq!(pts.len(), 3);
        // Below the knee: achieved tracks offered, SLO mostly met.
        assert!(pts[0].achieved_rps / pts[0].offered_rps > 0.9);
        assert!(pts[0].slo_attainment > 0.8, "{}", pts[0].slo_attainment);
        // Past the knee: p99 blows up, achieved saturates below
        // offered, SLO collapses.
        assert!(pts[2].p99_ms > 3.0 * pts[0].p99_ms, "{} vs {}", pts[2].p99_ms, pts[0].p99_ms);
        assert!(pts[2].achieved_rps < 0.95 * pts[2].offered_rps);
        assert!(pts[2].slo_attainment < pts[0].slo_attainment);
        // Tail ordering within a point.
        for p in &pts {
            assert!(p.p50_ms <= p.p99_ms && p.p99_ms <= p.p999_ms);
        }
    }

    #[test]
    fn parallel_curve_matches_sequential() {
        // The acceptance equivalence: fanning the util points out on
        // scoped threads must be bit-identical (exact float equality)
        // to the retained sequential sweep, in the same order.
        let d = u280_device();
        let utils = [0.4, 0.9, 1.15];
        let horizon = Duration::from_secs(3);
        for policy in [
            DispatchPolicy::RoundRobin,
            DispatchPolicy::JoinShortestQueue,
            DispatchPolicy::ExpertAffinity,
        ] {
            let par = fleet_curve(&d, 2, policy, 16, &utils, horizon, 11);
            let seq = fleet_curve_seq(&d, 2, policy, 16, &utils, horizon, 11);
            assert_eq!(par, seq, "parallel sweep diverged for {policy:?}");
        }
    }

    #[test]
    fn curve_is_deterministic() {
        let a = fleet_curve(
            &u280_device(),
            2,
            DispatchPolicy::RoundRobin,
            16,
            &[0.7],
            Duration::from_secs(5),
            42,
        );
        let b = fleet_curve(
            &u280_device(),
            2,
            DispatchPolicy::RoundRobin,
            16,
            &[0.7],
            Duration::from_secs(5),
            42,
        );
        assert_eq!(a[0].p99_ms, b[0].p99_ms);
        assert_eq!(a[0].achieved_rps, b[0].achieved_rps);
    }

    #[test]
    fn mixed_fleet_sed_strictly_cuts_p99_vs_jsq() {
        // The ROADMAP heterogeneous-fleets acceptance bar: on the
        // ZCU102-edge + U280-core fleet near the knee, expected-delay
        // dispatch strictly reduces the p99 e2e against both
        // queue-length (JSQ) and blind (RR) routing.
        let edge = demo_device(&Platform::zcu102());
        let core = u280_device();
        let horizon = Duration::from_secs(20);
        let run = |policy| {
            mixed_fleet_points(&edge, 4, &core, 2, policy, 16, &[0.85], horizon, 7)
                .remove(0)
        };
        let sed = run(DispatchPolicy::ShortestExpectedDelay);
        let jsq = run(DispatchPolicy::JoinShortestQueue);
        let rr = run(DispatchPolicy::RoundRobin);
        assert!(
            sed.p99_ms < jsq.p99_ms,
            "SED p99 {} !< JSQ p99 {} on the mixed fleet",
            sed.p99_ms,
            jsq.p99_ms
        );
        assert!(
            sed.p99_ms < rr.p99_ms,
            "SED p99 {} !< RR p99 {} on the mixed fleet",
            sed.p99_ms,
            rr.p99_ms
        );
        // Same offered traffic across policies.
        assert_eq!(sed.offered_rps, jsq.offered_rps);
        assert_eq!(sed.offered_rps, rr.offered_rps);
    }

    #[test]
    fn mixed_fleet_table_renders_all_policy_rows() {
        let t = mixed_fleet_table(
            &demo_device(&Platform::zcu102()),
            2,
            &u280_device(),
            1,
            16,
            &[0.6],
            Duration::from_secs(5),
            1,
        );
        assert_eq!(t.rows.len(), 4, "one row per policy");
        let text = t.render();
        assert!(text.contains("sed") && text.contains("jsq") && text.contains("round-robin"));
        assert!(text.contains("wrr"), "weighted-RR baseline row missing");
        assert!(text.contains("p99 (ms)"));
    }

    #[test]
    fn sed_beats_weighted_round_robin_on_the_mixed_fleet() {
        // The ISSUE satellite: WRR loads the tiers proportionally to
        // capacity but is blind to queue state, so on the mixed
        // ZCU102+U280 fleet near the knee the queue-aware
        // expected-delay signal must still cut the tail below it —
        // and WRR in turn must beat blind equal-share RR by a mile.
        let edge = demo_device(&Platform::zcu102());
        let core = u280_device();
        let horizon = Duration::from_secs(20);
        let run = |policy| {
            mixed_fleet_points(&edge, 4, &core, 2, policy, 16, &[0.85], horizon, 7)
                .remove(0)
        };
        let sed = run(DispatchPolicy::ShortestExpectedDelay);
        let wrr = run(DispatchPolicy::WeightedRoundRobin);
        let rr = run(DispatchPolicy::RoundRobin);
        assert!(
            sed.p99_ms < wrr.p99_ms,
            "SED p99 {} !< WRR p99 {} on the mixed fleet",
            sed.p99_ms,
            wrr.p99_ms
        );
        assert!(
            wrr.p99_ms < rr.p99_ms,
            "capacity-weighted RR p99 {} !< blind RR p99 {}",
            wrr.p99_ms,
            rr.p99_ms
        );
        assert_eq!(sed.offered_rps, wrr.offered_rps, "same offered traffic");
    }

    /// THE PR acceptance bar, on a synthetic device so the test stays
    /// milliseconds-cheap and the service model is fully pinned. The
    /// scenario constants (calm 0.25×peak, rare 2.6×peak bursts at
    /// 1/4 the calm dwell, SLO 3× largest-batch service, one-batch
    /// controller window, ceiling ceil(2.6/0.7) = 4) were chosen for
    /// wide margins: the burst-sized static fleet needs ~3 replicas
    /// around the clock while the controller rides ~80% of the run on
    /// one.
    #[test]
    fn autoscaler_meets_the_slo_with_fewer_device_seconds_than_any_adequate_static_fleet() {
        let dev = DeviceModel::from_latencies(
            "as-syn".into(),
            Duration::from_millis(2),
            Duration::from_millis(8),
            &[1, 2, 4, 8],
        );
        let study = autoscale_study(&dev, 5, Duration::from_secs(120), 0xF1EE7);
        let ctl = study.controller();
        assert_eq!(ctl.label, "autoscaler");
        assert!(
            ctl.meets,
            "controller attainment {:.4} below the 99% target",
            ctl.attainment
        );
        let smallest = study
            .smallest_static_meeting()
            .expect("some static fleet must meet the target");
        assert!(
            ctl.device_seconds < smallest.device_seconds,
            "controller {:.1} device-seconds !< smallest adequate static {} at {:.1}",
            ctl.device_seconds,
            smallest.label,
            smallest.device_seconds
        );
        assert!(
            study.saving_fraction().unwrap() > 0.03,
            "saving {:.3} suspiciously thin",
            study.saving_fraction().unwrap()
        );
        // The under-provisioned statics genuinely fail: the comparison
        // is not vacuous.
        assert!(!study.rows[0].meets, "static-1 cannot absorb 2.6x-peak bursts");
        let text = autoscale_table(&study).render();
        assert!(text.contains("autoscaler") && text.contains("saving"));
        assert!(text.contains("device-seconds"));
    }

    /// THE chaos acceptance bar, on the pinned synthetic device the
    /// fault scenarios were calibrated against (fill 4 ms, period
    /// 10 ms ⇒ service(8) = 84 ms, peak ≈ 95.2 req/s/device;
    /// num_experts = 0 so residency effects cannot shift the margins).
    /// Retry + failover must keep goodput ≥ 95% of offered through a
    /// two-device outage while the no-retry baseline measurably drops.
    #[test]
    fn chaos_study_retry_and_failover_preserve_goodput() {
        let dev = DeviceModel::from_latencies(
            "chaos-syn".into(),
            Duration::from_millis(4),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        );
        let study = chaos_study(&dev, 0, Duration::from_secs(30), 0xF1EE7);
        assert_eq!(study.slo, Duration::from_millis(168), "2x service(8)");
        let bare = study.row("jsq no-retry");
        assert!(
            bare.dropped >= 10,
            "no-retry baseline dropped only {} through a two-device outage",
            bare.dropped
        );
        for label in [
            "round-robin+retry",
            "jsq+retry",
            "sed+retry",
            "expert-affinity+retry",
            "jsq+retry+hedge",
        ] {
            let r = study.row(label);
            assert!(
                r.goodput >= 0.95,
                "{label}: goodput {:.4} below the 95% graceful-degradation bar",
                r.goodput
            );
            assert!(r.dropped < bare.dropped, "{label}: retries did not cut drops");
            assert!(r.retries >= 5, "{label}: only {} retries through the outage", r.retries);
            assert!(
                r.availability < 1.0 && r.availability > 0.9,
                "{label}: mean availability {:.4} inconsistent with a 2x1s/3-slot outage",
                r.availability
            );
        }
        // At least one outage run must have had work stranded on the
        // failed devices (per-row it can legitimately be zero when a
        // device happens to be idle at the fail instant — the
        // calibrated per-scenario assert lives in serve/mod.rs).
        let failovers: u64 = study.rows.iter().map(|r| r.failovers).sum();
        assert!(failovers > 0, "no outage run ever re-dispatched stranded work");
        let hedged = study.row("jsq+retry+hedge");
        assert!(hedged.hedges > 0, "hedge delay never fired");
        assert!(
            hedged.hedge_wins <= hedged.hedges,
            "hedge wins {} exceed hedges {}",
            hedged.hedge_wins,
            hedged.hedges
        );
    }

    /// Second chaos acceptance bar: losing a device for half the run
    /// craters the static fleet's SLO, and the autoscaler restores it
    /// without operator input.
    #[test]
    fn chaos_study_autoscaler_restores_the_slo_after_a_failure() {
        let dev = DeviceModel::from_latencies(
            "chaos-syn".into(),
            Duration::from_millis(4),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        );
        let study = chaos_study(&dev, 0, Duration::from_secs(30), 0xF1EE7);
        let stat = study.row("jsq static (long outage)");
        let auto = study.row("jsq autoscaled (long outage)");
        // No deadline in this scenario: nothing drops, the damage is
        // purely latency-side.
        assert_eq!(stat.dropped, 0);
        assert_eq!(auto.dropped, 0);
        assert!(
            auto.attainment >= 0.95,
            "autoscaled attainment {:.4} below 95% despite replacement capacity",
            auto.attainment
        );
        assert!(
            auto.attainment >= stat.attainment + 0.10,
            "autoscaler ({:.4}) does not separate from static ({:.4})",
            auto.attainment,
            stat.attainment
        );
        // Replacement capacity costs device-seconds — the ledger must
        // show the spend.
        assert!(auto.device_seconds > stat.device_seconds);
    }

    #[test]
    fn chaos_table_renders_every_row_and_is_deterministic() {
        let dev = DeviceModel::from_latencies(
            "chaos-syn".into(),
            Duration::from_millis(4),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        );
        let a = chaos_study(&dev, 0, Duration::from_secs(12), 5);
        let b = chaos_study(&dev, 0, Duration::from_secs(12), 5);
        assert_eq!(a.rows.len(), b.rows.len());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.p99_ms, y.p99_ms, "{}: scoped-thread fan-out nondeterministic", x.label);
            assert_eq!(x.dropped, y.dropped);
            assert_eq!(x.retries, y.retries);
        }
        let t = chaos_table(&a);
        assert_eq!(t.rows.len(), 8, "4 policies + hedge + no-retry + static/auto");
        let text = t.render();
        assert!(text.contains("jsq no-retry") && text.contains("autoscaled (long outage)"));
        assert!(text.contains("goodput") && text.contains("failovers"));
        assert!(!t.to_csv().is_empty());
    }

    /// THE overload acceptance bar, on the same pinned synthetic
    /// device as the chaos bars (service(8) = 84 ms, fleet peak
    /// ≈ 285.7 req/s): at 1.5× fleet peak the unprotected fleet
    /// misses the SLO for **every** class, tiered admission holds
    /// interactive attainment ≥ 99% on the offered basis, and
    /// brownout strictly reduces shed volume at the same interactive
    /// bar — paying in the accuracy-proxy column.
    #[test]
    fn overload_study_sheds_by_priority_and_brownout_cuts_rejections() {
        let dev = DeviceModel::from_latencies(
            "overload-syn".into(),
            Duration::from_millis(4),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        );
        let study = overload_study(&dev, 0, Duration::from_secs(30), 0xF1EE7);
        assert_eq!(study.slo, Duration::from_millis(252), "3x service(8)");
        let bare = study.row("unprotected (shadow)");
        let shed = study.row("admission+shedding");
        let brown = study.row("+brownout");
        // Shadow mode observes the mix but never enforces.
        assert_eq!(bare.rejected, 0, "shadow mode must not shed");
        for (c, a) in bare.class_attainment.iter().enumerate() {
            assert!(
                *a < 0.90,
                "unprotected class {c} attainment {a:.4} not collapsed at 1.5x peak"
            );
        }
        // Admission + shedding: background pays, interactive is held.
        assert!(shed.rejected > 0, "no shedding at 1.5x peak");
        assert!(
            shed.class_attainment[0] >= 0.99,
            "interactive attainment {:.4} below the 99% bar under tiered admission",
            shed.class_attainment[0]
        );
        assert!(
            shed.class_attainment[2] < shed.class_attainment[0],
            "shedding must cost background ({:.4}) more than interactive ({:.4})",
            shed.class_attainment[2],
            shed.class_attainment[0]
        );
        assert!(
            shed.interactive_p99_ms < bare.interactive_p99_ms,
            "bounding the queue must cut the interactive p99 ({} vs {})",
            shed.interactive_p99_ms,
            bare.interactive_p99_ms
        );
        // Brownout absorbs load admission alone had to shed: strictly
        // fewer rejections at the same interactive bar.
        assert!(
            brown.class_attainment[0] >= 0.99,
            "interactive attainment {:.4} below the 99% bar with brownout",
            brown.class_attainment[0]
        );
        assert!(
            brown.rejected < shed.rejected,
            "brownout did not reduce shed volume ({} vs {})",
            brown.rejected,
            shed.rejected
        );
        assert!(brown.brownout_windows > 0, "brownout never engaged at 1.5x peak");
        assert!(brown.degraded_completions > 0, "no work served on the degraded table");
        // The accuracy proxy is the exact per-request cost — degraded
        // service is never free.
        assert_eq!(brown.accuracy_cost, brown.degraded_completions as f64 * 0.01);
        // Shadow and shed rows never degrade.
        assert_eq!(bare.degraded_completions, 0);
        assert_eq!(shed.accuracy_cost, 0.0);
    }

    #[test]
    fn overload_table_renders_every_row_and_is_deterministic() {
        let dev = DeviceModel::from_latencies(
            "overload-syn".into(),
            Duration::from_millis(4),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        );
        let a = overload_study(&dev, 0, Duration::from_secs(12), 5);
        let b = overload_study(&dev, 0, Duration::from_secs(12), 5);
        assert_eq!(a.rows.len(), 3);
        assert_eq!(a.rows.len(), b.rows.len());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.rejected, y.rejected, "{}: fan-out nondeterministic", x.label);
            assert_eq!(x.class_attainment, y.class_attainment);
            assert_eq!(x.interactive_p99_ms, y.interactive_p99_ms);
            assert_eq!(x.accuracy_cost, y.accuracy_cost);
        }
        let t = overload_table(&a);
        assert_eq!(t.rows.len(), 3);
        let text = t.render();
        assert!(text.contains("unprotected (shadow)") && text.contains("+brownout"));
        assert!(text.contains("rejected") && text.contains("acc. cost"));
        assert!(!t.to_csv().is_empty());
    }

    /// The shard study on the calibrated synthetic device (service(8)
    /// = 84 ms, peak ≈ 95.2 req/s): replicating the hot expert holds
    /// goodput ≥ 95% through its home device's outage where RF=1
    /// cannot, and the rebalancing controller beats static placement
    /// on p99 by better than 2× under drift.
    #[test]
    fn shard_study_shows_replication_and_rebalancing_margins() {
        let dev = DeviceModel::from_latencies(
            "shard-syn".into(),
            Duration::from_millis(4),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        );
        let study = shard_study(&dev, Duration::from_secs(30), 0xF1EE7);
        let rf1 = study.row("rf=1 outage");
        let rf2 = study.row("rf=2 outage");
        // RF=1: the hot expert lives only on the dead device, so its
        // traffic drops as no_replica and goodput falls below the bar.
        assert!(rf1.no_replica_drops > 0, "outage never hit the hot expert at RF=1");
        assert_eq!(rf1.dropped, rf1.no_replica_drops, "only no-replica drops expected");
        assert!(
            rf1.goodput < 0.95,
            "RF=1 goodput {:.4} unexpectedly survived the hot-expert outage",
            rf1.goodput
        );
        // RF=2: the second replica absorbs the outage.
        assert!(
            rf2.goodput >= 0.95,
            "RF=2 goodput {:.4} below the 95% failover bar",
            rf2.goodput
        );
        assert!(rf2.dropped < rf1.dropped, "replication must cut drops");
        let st = study.row("static drift");
        let rb = study.row("rebalanced drift");
        assert_eq!(st.rebalances, 0, "static row must never rebalance");
        assert!(rb.rebalances > 0, "rebalancer never moved a replica under drift");
        assert!(rb.replica_adds > 0, "rebalancer never grew a hot replica");
        assert!(
            rb.p99_ms * 2.0 < st.p99_ms,
            "rebalancing p99 {:.1} ms not < half of static {:.1} ms under drift",
            rb.p99_ms,
            st.p99_ms
        );
    }

    #[test]
    fn shard_table_renders_every_row_and_is_deterministic() {
        let dev = DeviceModel::from_latencies(
            "shard-syn".into(),
            Duration::from_millis(4),
            Duration::from_millis(10),
            &[1, 2, 4, 8],
        );
        let a = shard_study(&dev, Duration::from_secs(12), 5);
        let b = shard_study(&dev, Duration::from_secs(12), 5);
        assert_eq!(a.rows.len(), 4);
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.offered, y.offered, "{}: fan-out nondeterministic", x.label);
            assert_eq!(x.dropped, y.dropped);
            assert_eq!(x.rerouted, y.rerouted);
            assert_eq!(x.p99_ms, y.p99_ms);
        }
        let t = shard_table(&a);
        assert_eq!(t.rows.len(), 4);
        let text = t.render();
        assert!(text.contains("rf=2 outage") && text.contains("rebalanced drift"));
        assert!(text.contains("no-replica") && text.contains("replica adds"));
        assert!(!t.to_csv().is_empty());
    }

    #[test]
    fn max_users_search_finds_a_nontrivial_boundary() {
        let dev = DeviceModel::from_latencies(
            "cl-syn".into(),
            Duration::from_millis(2),
            Duration::from_millis(8),
            &[1, 2, 4, 8],
        );
        let think = Duration::from_millis(200);
        let horizon = Duration::from_secs(20);
        let (users, p) = max_users_at_slo(&dev, 2, think, 0.99, horizon, 3);
        // The returned point itself meets the target, and batching
        // must carry well more than one user per device.
        assert!(p.slo_attainment >= 0.99, "{}", p.slo_attainment);
        assert!(users > 4, "boundary {users} suspiciously small");
        // The boundary is real: a far larger population must miss it.
        let mut flood = ServeConfig::uniform(
            dev.clone(),
            2,
            Workload::ClosedLoop { users: users * 6, think_time: think },
        );
        flood.horizon = horizon;
        flood.seed = 3;
        let r = simulate_fleet(&flood);
        assert!(
            r.slo_attainment(attainable_slo(&dev)) < 0.99,
            "6x the boundary population still meets the SLO — search failed low"
        );
        let t = max_users_table(&[("syn", &dev)], 2, Duration::from_secs(10), 3);
        assert_eq!(t.rows.len(), 1);
        assert!(t.render().contains("max users"));
    }

    #[test]
    fn table_renders_all_points() {
        let pts = fleet_curve(
            &u280_device(),
            1,
            DispatchPolicy::JoinShortestQueue,
            16,
            &[0.5, 1.1],
            Duration::from_secs(4),
            1,
        );
        let t = curve_table("Serving: test", &pts);
        assert_eq!(t.rows.len(), 2);
        assert!(t.render().contains("p99 (ms)"));
        assert!(!t.to_csv().is_empty());
    }
}
