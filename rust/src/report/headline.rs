//! The paper's headline claims (§I / abstract), recomputed from our
//! measured points: throughput and efficiency improvements over
//! Edge-MoE and the GPU.

use crate::baselines::PerfPoint;
use crate::util::table::{f2, Table};

/// Headline ratios given the four Table II points
/// [GPU, Edge-MoE, UbiMoE-ZCU102, UbiMoE-U280].
#[derive(Clone, Copy, Debug)]
pub struct Headline {
    /// 1.34× in the paper.
    pub speedup_zcu102_vs_edge: f64,
    /// 3.35× in the paper.
    pub speedup_u280_vs_edge: f64,
    /// 1.75× in the paper.
    pub eff_zcu102_vs_edge: f64,
    /// 1.54× in the paper.
    pub eff_u280_vs_edge: f64,
    /// 7.85× in the paper (ZCU102 vs GPU efficiency).
    pub eff_zcu102_vs_gpu: f64,
    /// 1.77× in the paper (ZCU102 vs GPU speedup).
    pub speedup_zcu102_vs_gpu: f64,
}

pub fn headline(points: &[PerfPoint]) -> Headline {
    assert!(points.len() >= 4, "need [gpu, edge, ubi_z, ubi_u]");
    let (gpu, edge, ubi_z, ubi_u) = (&points[0], &points[1], &points[2], &points[3]);
    Headline {
        speedup_zcu102_vs_edge: ubi_z.speedup_over(edge),
        speedup_u280_vs_edge: ubi_u.speedup_over(edge),
        eff_zcu102_vs_edge: ubi_z.efficiency_gain_over(edge),
        eff_u280_vs_edge: ubi_u.efficiency_gain_over(edge),
        eff_zcu102_vs_gpu: ubi_z.efficiency_gain_over(gpu),
        speedup_zcu102_vs_gpu: ubi_z.speedup_over(gpu),
    }
}

pub fn headline_table(h: &Headline) -> Table {
    let mut t = Table::new(
        "Headline claims: paper vs this reproduction",
        &["Claim", "Paper", "Measured"],
    );
    t.row(&["ZCU102 speedup vs Edge-MoE".into(), "1.34x".into(), format!("{}x", f2(h.speedup_zcu102_vs_edge))]);
    t.row(&["U280 speedup vs Edge-MoE".into(), "3.35x".into(), format!("{}x", f2(h.speedup_u280_vs_edge))]);
    t.row(&["ZCU102 efficiency vs Edge-MoE".into(), "1.75x".into(), format!("{}x", f2(h.eff_zcu102_vs_edge))]);
    t.row(&["U280 efficiency vs Edge-MoE".into(), "1.54x".into(), format!("{}x", f2(h.eff_u280_vs_edge))]);
    t.row(&["ZCU102 speedup vs GPU".into(), "1.77x".into(), format!("{}x", f2(h.speedup_zcu102_vs_gpu))]);
    t.row(&["ZCU102 efficiency vs GPU".into(), "7.85x".into(), format!("{}x", f2(h.eff_zcu102_vs_gpu))]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::published::paper_rows;

    #[test]
    fn paper_rows_reproduce_paper_headline() {
        let points = vec![
            paper_rows::gpu_v100s(),
            paper_rows::edge_moe(),
            paper_rows::ubimoe_zcu102(),
            paper_rows::ubimoe_u280(),
        ];
        let h = headline(&points);
        assert!((h.speedup_zcu102_vs_edge - 1.34).abs() < 0.02);
        assert!((h.speedup_u280_vs_edge - 3.35).abs() < 0.02);
        // Paper Table II prints Edge-MoE at 4.83 GOPS/W though its own
        // row implies 4.96 — efficiency ratios reproduce to ~5% only.
        assert!((h.eff_zcu102_vs_edge - 1.75).abs() < 0.09);
        assert!((h.eff_u280_vs_edge - 1.54).abs() < 0.09);
        assert!((h.eff_zcu102_vs_gpu - 7.85).abs() < 0.06);
        assert!((h.speedup_zcu102_vs_gpu - 1.77).abs() < 0.02);
    }

    #[test]
    fn measured_headline_has_right_shape() {
        // Our simulated points: every headline ratio must at least
        // point the same direction (>1) as the paper.
        let (_, points) = crate::report::tables::table2();
        let h = headline(&points);
        assert!(h.speedup_zcu102_vs_edge > 1.0, "{h:?}");
        assert!(h.speedup_u280_vs_edge > h.speedup_zcu102_vs_edge, "{h:?}");
        assert!(h.eff_zcu102_vs_edge > 1.0, "{h:?}");
        assert!(h.eff_u280_vs_edge > 1.0, "{h:?}");
        assert!(h.eff_zcu102_vs_gpu > 2.0, "{h:?}");
        assert!(h.speedup_zcu102_vs_gpu > 1.0, "{h:?}");
        let t = headline_table(&h);
        assert_eq!(t.rows.len(), 6);
    }
}
