//! Table I / II / III renderers.

use crate::baselines::{edge_moe, gpu, published, PerfPoint};
use crate::models::{m3vit_small, vit_s, vit_t};
use crate::report::{deploy_many, DeploySpec, Deployment};
use crate::resources::Platform;
use crate::util::table::{f1, f2, f3, i0, kfmt, Table};

/// Table I: resource consumption of deploying M3ViT on both platforms.
/// BRAM is reported in BRAM36 units to match the paper's column.
/// The two platform deployments run concurrently (deploy_many).
pub fn table1() -> (Table, Vec<Deployment>) {
    let mut t = Table::new(
        "Table I: Resource Consumption of Deploying M3ViT",
        &["Platform", "DSPs", "BRAMs (36Kb)", "LUTs", "FFs"],
    );
    let deps = deploy_many(&[
        DeploySpec::new(m3vit_small(), Platform::zcu102(), 16, 32),
        DeploySpec::new(m3vit_small(), Platform::u280(), 16, 32),
    ]);
    for d in &deps {
        let r = &d.has.resources;
        t.row(&[
            d.platform.name.to_string(),
            i0(r.dsp),
            i0(r.bram18 / 2.0),
            kfmt(r.lut),
            kfmt(r.ff),
        ]);
    }
    (t, deps)
}

/// Table II: GPU vs Edge-MoE vs UbiMoE (ZCU102, U280) on M3ViT.
pub fn table2() -> (Table, Vec<PerfPoint>) {
    let model = m3vit_small();
    let deps = deploy_many(&[
        DeploySpec::new(model.clone(), Platform::zcu102(), 16, 32),
        DeploySpec::new(model.clone(), Platform::u280(), 16, 32),
    ]);
    let points = vec![
        gpu::simulate_gpu(&model),
        edge_moe::simulate_edge_moe(&model),
        deps[0].perf_point("UbiMoE"),
        deps[1].perf_point("UbiMoE"),
    ];
    let t = perf_table("Table II: Comparison with GPU and Edge-MoE on M3ViT", &points);
    (t, points)
}

/// Table III: prior transformer accelerators vs UbiMoE-E / UbiMoE-C.
/// HeatViT and TECS'23 rows are their published numbers (as in the
/// paper); UbiMoE-E/-C are our INT16 deployments of ViT-T / ViT-S.
pub fn table3() -> (Table, Vec<PerfPoint>) {
    let deps = deploy_many(&[
        DeploySpec::new(vit_t(), Platform::zcu102(), 16, 16),
        DeploySpec::new(vit_s(), Platform::u280(), 16, 16),
    ]);
    let points = vec![
        published::heatvit(),
        deps[0].perf_point("UbiMoE-E"),
        published::tecs23(),
        deps[1].perf_point("UbiMoE-C"),
    ];
    let mut t = Table::new(
        "Table III: Comparison with Previous FPGA Implementations",
        &["Attribute", "HeatViT", "UbiMoE-E", "TECS'23", "UbiMoE-C"],
    );
    let models = ["DeiT-S", "ViT-T", "BERT-B", "ViT-S"];
    t.row(&cells("Model", &points, |_, i| models[i].to_string()));
    t.row(&cells("Platform", &points, |p, _| p.platform.clone()));
    t.row(&cells("Bit-width", &points, |p, _| p.bitwidth.clone()));
    t.row(&cells("Freq. (MHz)", &points, |p, _| i0(p.freq_mhz)));
    t.row(&cells("Power (W)", &points, |p, _| f2(p.power_w)));
    t.row(&cells("Latency (ms)", &points, |p, _| {
        if p.latency_ms.is_nan() {
            "-".into()
        } else {
            f2(p.latency_ms)
        }
    }));
    t.row(&cells("Throughput (GOPS)", &points, |p, _| f1(p.gops)));
    t.row(&cells("Efficiency (GOPS/W)", &points, |p, _| f2(p.gops_per_w())));
    (t, points)
}

fn cells(
    label: &str,
    points: &[PerfPoint],
    f: impl Fn(&PerfPoint, usize) -> String,
) -> Vec<String> {
    let mut v = vec![label.to_string()];
    v.extend(points.iter().enumerate().map(|(i, p)| f(p, i)));
    v
}

/// Render a Table II-style perf comparison (systems as columns).
pub fn perf_table(title: &str, points: &[PerfPoint]) -> Table {
    let mut header = vec!["Attribute".to_string()];
    header.extend(points.iter().map(|p| p.system.clone()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &hdr);
    t.row(&cells("Platform", points, |p, _| p.platform.clone()));
    t.row(&cells("Bit-width", points, |p, _| p.bitwidth.clone()));
    t.row(&cells("Frequency (MHz)", points, |p, _| i0(p.freq_mhz)));
    t.row(&cells("Power (W)", points, |p, _| f2(p.power_w)));
    t.row(&cells("Latency (ms)", points, |p, _| f2(p.latency_ms)));
    t.row(&cells("Throughput (GOPS)", points, |p, _| f2(p.gops)));
    t.row(&cells("Efficiency (GOPS/W)", points, |p, _| f3(p.gops_per_w())));
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_fits_devices() {
        let (t, deps) = table1();
        assert_eq!(t.rows.len(), 2);
        for d in &deps {
            assert!(d.has.resources.fits(&d.platform.budget()), "{}", d.platform.name);
        }
    }

    #[test]
    fn table2_preserves_paper_ordering() {
        // The shape that must hold: UbiMoE-ZCU102 beats Edge-MoE beats
        // GPU on throughput; U280 has the highest throughput; ZCU102
        // UbiMoE has the best efficiency among W16A32 FPGA points.
        let (_, p) = table2();
        let (gpu, edge, ubi_z, ubi_u) = (&p[0], &p[1], &p[2], &p[3]);
        assert!(ubi_z.gops > edge.gops, "UbiMoE {} !> Edge-MoE {}", ubi_z.gops, edge.gops);
        assert!(edge.gops > gpu.gops, "Edge-MoE {} !> GPU {}", edge.gops, gpu.gops);
        assert!(ubi_u.gops > ubi_z.gops, "U280 {} !> ZCU102 {}", ubi_u.gops, ubi_z.gops);
        assert!(ubi_z.gops_per_w() > edge.gops_per_w());
        assert!(ubi_z.gops_per_w() > ubi_u.gops_per_w(), "paper: 8.438 > 7.451");
        assert!(gpu.gops_per_w() < edge.gops_per_w());
    }

    #[test]
    fn table3_int16_beats_w16a32_throughput() {
        // Table III's INT16 single-DSP lanes must outrun the W16A32
        // M3ViT design on the same platform class.
        let (_, p3) = table3();
        let (_, p2) = table2();
        let ubi_e = &p3[1];
        let ubi_z = &p2[2];
        assert!(
            ubi_e.gops > ubi_z.gops,
            "INT16 ViT-T {} !> W16A32 M3ViT {}",
            ubi_e.gops,
            ubi_z.gops
        );
    }

    #[test]
    fn tables_render_nonempty() {
        let (t1, _) = table1();
        assert!(t1.render().contains("ZCU102"));
        let (t2, _) = table2();
        assert!(t2.render().contains("Edge-MoE"));
        let (t3, _) = table3();
        assert!(t3.render().contains("UbiMoE-C"));
    }
}
