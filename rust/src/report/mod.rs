//! Report layer: regenerates every table and figure of the paper's
//! evaluation from the simulator + HAS + baselines, plus the
//! deployment-scale serving study ([`serving`]: fleet
//! latency–throughput curves the paper stops short of). Each bench
//! target under benches/ is a thin wrapper over one function here, so
//! the exact same code paths are unit-tested.

pub mod figures;
pub mod headline;
pub mod plan;
pub mod serving;
pub mod tables;

use crate::baselines::PerfPoint;
use crate::has::{self, HasConfig, HasResult};
use crate::models::ModelConfig;
use crate::resources::Platform;
use crate::sim::engine::SimResult;

/// A fully evaluated UbiMoE deployment: search result + simulation.
#[derive(Clone, Debug)]
pub struct Deployment {
    pub model: ModelConfig,
    pub platform: Platform,
    pub has: HasResult,
    pub sim: SimResult,
}

/// Run HAS for (model, platform) and simulate the chosen design.
///
/// Goes through the persistent design cache ([`has::cache`]): on a
/// warm process the whole deployment — search result *and* operating
/// point — is read back from the artifact with zero GA evaluations and
/// zero cycle sims (asserted in `rust/tests/design_cache.rs`). A
/// cache-loaded deployment's `sim.timeline` is empty (the scalar
/// fields the tables read are all persisted); Fig. 3 renders from its
/// own simulation, not from here.
pub fn deploy(model: &ModelConfig, platform: &Platform, q_bits: u32, a_bits: u32) -> Deployment {
    let cfg = HasConfig::deployment(q_bits, a_bits);
    // Bit-width timing rule (Table III) shared with serve/: see
    // Platform::with_bitwidth_timing.
    let platform = platform.clone().with_bitwidth_timing(a_bits);
    let art = has::cache::cached_design(model, &platform, &cfg);
    Deployment { model: model.clone(), platform, has: art.has, sim: art.sim }
}

/// One (model, platform, bit-width) cell of a report table.
#[derive(Clone, Debug)]
pub struct DeploySpec {
    pub model: ModelConfig,
    pub platform: Platform,
    pub q_bits: u32,
    pub a_bits: u32,
}

impl DeploySpec {
    pub fn new(model: ModelConfig, platform: Platform, q_bits: u32, a_bits: u32) -> DeploySpec {
        DeploySpec { model, platform, q_bits, a_bits }
    }
}

/// Deploy every spec concurrently on scoped threads. Each deployment
/// is an independent deterministic HAS + simulation, so the results
/// are identical to the sequential loop and returned in input order —
/// this is what makes dense multi-platform report sweeps cheap.
pub fn deploy_many(specs: &[DeploySpec]) -> Vec<Deployment> {
    if specs.len() <= 1 {
        return specs
            .iter()
            .map(|s| deploy(&s.model, &s.platform, s.q_bits, s.a_bits))
            .collect();
    }
    std::thread::scope(|scope| {
        let handles: Vec<_> = specs
            .iter()
            .map(|s| scope.spawn(move || deploy(&s.model, &s.platform, s.q_bits, s.a_bits)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("deploy worker panicked"))
            .collect()
    })
}

impl Deployment {
    pub fn perf_point(&self, label: &str) -> PerfPoint {
        PerfPoint {
            system: label.into(),
            platform: self.platform.name.into(),
            bitwidth: format!("W{}A{}", self.has.hw.q_bits, self.has.hw.a_bits),
            freq_mhz: self.platform.freq_mhz,
            power_w: self.sim.power_w,
            latency_ms: self.sim.latency_ms,
            gops: self.sim.gops,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::m3vit_small;

    #[test]
    fn deploy_produces_consistent_point() {
        let d = deploy(&m3vit_small(), &Platform::zcu102(), 16, 32);
        let p = d.perf_point("UbiMoE");
        assert_eq!(p.platform, "ZCU102");
        assert!(p.gops > 0.0 && p.power_w > 0.0 && p.latency_ms > 0.0);
        assert!((p.gops - d.sim.gops).abs() < 1e-9);
    }

    #[test]
    fn int16_u280_runs_at_250mhz() {
        let d = deploy(&crate::models::vit_s(), &Platform::u280(), 16, 16);
        assert_eq!(d.platform.freq_mhz, 250.0);
        assert_eq!(d.perf_point("x").bitwidth, "W16A16");
    }

    #[test]
    fn deploy_many_matches_sequential_deploys() {
        let specs = vec![
            DeploySpec::new(m3vit_small(), Platform::zcu102(), 16, 32),
            DeploySpec::new(crate::models::vit_t(), Platform::zcu102(), 16, 16),
        ];
        let par = deploy_many(&specs);
        assert_eq!(par.len(), 2);
        for (d, s) in par.iter().zip(&specs) {
            let seq = deploy(&s.model, &s.platform, s.q_bits, s.a_bits);
            assert_eq!(d.has.hw, seq.has.hw, "{}", s.model.name);
            assert_eq!(d.sim.latency_ms, seq.sim.latency_ms, "{}", s.model.name);
            assert_eq!(d.model.name, s.model.name);
        }
    }
}
