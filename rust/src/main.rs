//! UbiMoE CLI: run the paper's experiments from one binary.
//!
//! Subcommands (hand-rolled parsing; clap is not in the vendored set):
//!   tables             print Tables I, II, III + headline ratios
//!   search  [--platform P] [--model M] [--int16]   run HAS
//!   timeline [--platform P]                        Fig. 3b
//!   reorder                                        Fig. 4
//!   placement [--platform P]                       Fig. 5
//!   run     [--model M] [--requests N] [--sequential]  e2e inference
//!   serve   [--platform P] [--model M] [--devices N] [--policy rr|wrr|jsq|affinity|sed]
//!           [--study] [--faults] [--overload]      fleet latency–throughput curve,
//!           [--shard]                              full figure set, chaos table,
//!                                                  overload-protection table, or
//!                                                  expert-sharding table
//!           [--trace F] [--timeseries F]           observed single run: JSONL event
//!                                                  trace + windowed gauge CSV
//!   trace   analyze <trace.jsonl>                  offline latency breakdown +
//!                                                  utilization/incident timelines
//!   deploy  <spec.ini>                             evaluate a deployment spec
//!   plan    [--small]                              fleet↔hardware co-design search:
//!                                                  Pareto frontier over
//!                                                  (device-seconds, p99, energy)
//!   cache   stats | gc --max-bytes N               design-cache maintenance
//!   info                                           artifact inventory
//!
//! Every subcommand honors the global `--design-cache DIR` flag
//! (default `.ubimoe-cache/`, `none` disables): a persistent,
//! content-addressed cache of HAS + cycle-sim design artifacts, so
//! repeated studies skip all search and simulation work.

use anyhow::{bail, Context, Result};

use ubimoe::models;
use ubimoe::report::{deploy, figures, headline, tables};
use ubimoe::resources::Platform;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    configure_design_cache(&mut args);
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Global `--design-cache DIR` flag (valid on every subcommand): the
/// persistent design-artifact cache directory, default
/// `.ubimoe-cache/`; `--design-cache none` disables caching. Consumed
/// here so subcommand parsers never see it.
fn configure_design_cache(args: &mut Vec<String>) {
    let dir = match args.iter().position(|a| a == "--design-cache") {
        Some(i) => match args.get(i + 1).cloned() {
            // Refuse a missing or flag-shaped value instead of silently
            // disabling the cache or swallowing another flag.
            Some(v) if !v.starts_with("--") => {
                args.drain(i..i + 2);
                v
            }
            _ => {
                eprintln!(
                    "error: --design-cache needs a value (a directory, or 'none' to disable)"
                );
                std::process::exit(2);
            }
        },
        None => ".ubimoe-cache".into(),
    };
    let dir = match dir.as_str() {
        "none" | "off" => None,
        d => Some(std::path::PathBuf::from(d)),
    };
    ubimoe::has::cache::set_global_dir(dir);
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn platform_arg(args: &[String]) -> Result<Platform> {
    let name = flag_value(args, "--platform").unwrap_or("zcu102");
    Platform::by_name(name).with_context(|| format!("unknown platform {name}"))
}

fn model_arg(args: &[String], default: &str) -> Result<models::ModelConfig> {
    let name = flag_value(args, "--model").unwrap_or(default);
    models::by_name(name).with_context(|| format!("unknown model {name}"))
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(|s| s.as_str()) {
        Some("tables") => cmd_tables(),
        Some("search") => cmd_search(&args[1..]),
        Some("timeline") => cmd_timeline(&args[1..]),
        Some("reorder") => cmd_reorder(&args[1..]),
        Some("placement") => cmd_placement(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("trace") => cmd_trace(&args[1..]),
        Some("deploy") => cmd_deploy(&args[1..]),
        Some("plan") => cmd_plan(&args[1..]),
        Some("cache") => cmd_cache(&args[1..]),
        Some("info") => cmd_info(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other} (try `help`)"),
    }
}

fn print_help() {
    println!(
        "ubimoe — UbiMoE paper reproduction\n\
         \n\
         USAGE: ubimoe <subcommand> [flags]\n\
         \n\
         tables                         reproduce Tables I, II, III + headline\n\
         search    [--platform P] [--model M] [--int16]  2-stage HAS (Alg. 1)\n\
         timeline  [--platform P]       Fig. 3b double-buffer timeline\n\
         reorder                        Fig. 4 patch-reorder traffic\n\
         placement [--platform P]       Fig. 5 SLR floorplan\n\
         run       [--model M] [--requests N] [--pipeline|--sequential]\n\
                                        end-to-end inference via PJRT artifacts\n\
         serve     [--platform P] [--model M] [--devices N]\n\
                   [--policy rr|wrr|jsq|affinity|sed]\n\
                   [--seconds S]        DES fleet-serving latency-throughput curve\n\
                                        (S = arrival horizon, default 10; load\n\
                                        points simulated concurrently)\n\
                   [--study]            full ZCU102-vs-U280 1-8 device figure set\n\
                                        + mixed edge/core policy table (RR/WRR/\n\
                                        JSQ/SED) + SLO-driven autoscaling vs\n\
                                        static fleets + chaos + overload +\n\
                                        sharding tables + closed-loop\n\
                                        max-users-at-SLO rows (honors only\n\
                                        --seconds;\n\
                                        searches and sweeps run on scoped\n\
                                        threads; the autoscale horizon is\n\
                                        12x --seconds so bursts stay rare)\n\
                   [--faults]           chaos table: scripted outages with\n\
                                        failover + retries + hedging across\n\
                                        dispatch policies, a no-retry baseline,\n\
                                        and static-vs-autoscaled SLO recovery\n\
                                        (3x --seconds horizon; fixed x3 fleet)\n\
                   [--overload]         overload-protection table: 1.5x fleet\n\
                                        peak under unprotected / tiered\n\
                                        admission + priority shedding /\n\
                                        +brownout degradation, with per-class\n\
                                        SLO attainment and the accuracy-proxy\n\
                                        cost of degraded service (3x --seconds\n\
                                        horizon; fixed x3 fleet)\n\
                   [--shard]            expert-sharding table: top-1 Zipf\n\
                                        routing over 8 experts — RF=1 vs RF=2\n\
                                        through a hot-expert home-device\n\
                                        outage, and static vs rebalanced\n\
                                        placement under popularity drift\n\
                                        (3x --seconds horizon; fixed fleets)\n\
                   [--trace F.jsonl]    observed single run (not --study/\n\
                   [--timeseries F.csv] --faults): write the deterministic\n\
                                        event trace and/or windowed gauge CSV;\n\
                                        honors --util U (offered load fraction,\n\
                                        default 0.7) and --inject-outage (demo\n\
                                        scripted device-0 outage with failover)\n\
         trace analyze <trace.jsonl>    offline analyzer: latency breakdown\n\
                   [--slo-ms X]         (queue/service/padding/backoff/failover\n\
                   [--buckets N]        p50+p99), per-device utilization\n\
                                        timeline, ASCII incident timeline\n\
         deploy    <spec.ini>           evaluate a deployment spec file\n\
         plan      [--small]            fleet<->hardware co-design search: GA (or\n\
                                        exhaustive, for tiny spaces) over fleet\n\
                                        composition x bit-width tier x dispatch\n\
                                        policy x autoscale preset, fitness from\n\
                                        memoized serving-DES runs; prints the\n\
                                        Pareto frontier over (device-seconds,\n\
                                        p99, energy) + a per-scenario replay.\n\
                                        Warm reruns (same --design-cache) do\n\
                                        zero DES event loops. --small runs the\n\
                                        hand-checkable 2-template fixture\n\
         cache stats                    design-cache artifact count + bytes\n\
                                        + process work counters\n\
         cache gc --max-bytes N         evict oldest artifacts down to N bytes\n\
                                        (suffixes k/m/g; stale temps always\n\
                                        swept)\n\
         info                           artifact inventory\n\
         \n\
         global: --design-cache DIR     persistent design-artifact cache\n\
                                        (default .ubimoe-cache/; 'none' disables).\n\
                                        Warm runs skip all HAS + cycle-sim work.\n\
         \n\
         platforms: zcu102 u280 u250 v100s    models: {}",
        models::all_names().join(" ")
    );
}

fn cmd_tables() -> Result<()> {
    let (t1, _) = tables::table1();
    println!("{}", t1.render());
    let (t2, points) = tables::table2();
    println!("{}", t2.render());
    let (t3, _) = tables::table3();
    println!("{}", t3.render());
    let h = headline::headline(&points);
    println!("{}", headline::headline_table(&h).render());
    Ok(())
}

fn cmd_search(args: &[String]) -> Result<()> {
    let platform = platform_arg(args)?;
    let model = model_arg(args, "m3vit-small")?;
    let (q, a) = if args.iter().any(|x| x == "--int16") { (16, 16) } else { (16, 32) };
    let d = deploy(&model, &platform, q, a);
    println!("model     : {}", model.name);
    println!("platform  : {} @ {} MHz", d.platform.name, d.platform.freq_mhz);
    println!("chosen    : {}", d.has.hw);
    println!("stage     : {:?} (fit score {:.3})", d.has.stage, d.has.fit_score);
    println!(
        "L_MSA     : {:.0} cycles ({:.3} ms)",
        d.has.l_msa,
        d.platform.cycles_to_ms(d.has.l_msa)
    );
    println!(
        "L_MoE     : {:.0} cycles ({:.3} ms)",
        d.has.l_moe,
        d.platform.cycles_to_ms(d.has.l_moe)
    );
    println!(
        "resources : {:.0} DSP, {:.0} BRAM18, {:.1}K LUT, {:.1}K FF",
        d.has.resources.dsp,
        d.has.resources.bram18,
        d.has.resources.lut / 1e3,
        d.has.resources.ff / 1e3
    );
    println!(
        "model e2e : {:.2} ms, {:.1} GOPS, {:.2} W, {:.3} GOPS/W",
        d.sim.latency_ms, d.sim.gops, d.sim.power_w, d.sim.gops_per_w
    );
    println!("GA        : {} evaluations", d.has.ga_evaluations);
    Ok(())
}

fn cmd_timeline(args: &[String]) -> Result<()> {
    let platform = platform_arg(args)?;
    let (overlapped, sequential, speedup) = figures::fig3_timeline(&platform);
    println!("Fig. 3b — double-buffered timeline ({}):\n", platform.name);
    println!("{}", overlapped.render(100));
    println!("sequential (no double buffering):\n");
    println!("{}", sequential.render(100));
    println!("double-buffering speedup: {speedup:.3}x");
    Ok(())
}

fn cmd_reorder(_args: &[String]) -> Result<()> {
    let t = figures::fig4_reorder(&models::m3vit_small(), 32);
    println!("{}", t.render());
    Ok(())
}

fn cmd_placement(args: &[String]) -> Result<()> {
    let platform = platform_arg(args)?;
    let (txt, _) = figures::fig5_placement(&platform);
    println!("{txt}");
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    use ubimoe::coordinator::{run_pipeline, run_sequential, Blk2Stage, MsaStage};
    use ubimoe::runtime::model::{RuntimeModel, BLK2_KINDS, MSA_KINDS};
    use ubimoe::runtime::tensor::Tensor;

    let model = model_arg(args, "m3vit-tiny")?;
    let requests: usize = flag_value(args, "--requests").unwrap_or("8").parse()?;
    let sequential = args.iter().any(|x| x == "--sequential");
    let dir = ubimoe::runtime::artifacts_dir();
    if !ubimoe::runtime::artifacts_available() {
        bail!("no artifacts under {} — run `make artifacts` first", dir.display());
    }

    eprintln!("loading {} artifacts from {} ...", model.name, dir.display());
    let rt = RuntimeModel::load(&dir, model.name)?;
    eprintln!(
        "loaded: {} params, batches {:?}",
        rt.weights.total_params(),
        rt.batches()
    );

    // Synthetic request images (seeded), embedded to tokens.
    let t0 = std::time::Instant::now();
    let mut inputs = Vec::with_capacity(requests);
    for i in 0..requests {
        let img = Tensor::random(
            vec![1, model.in_chans, model.img_size, model.img_size],
            0.5,
            100 + i as u64,
        );
        inputs.push(rt.embed(&img)?);
    }
    eprintln!("embedded {requests} requests in {:?}", t0.elapsed());

    let t1 = std::time::Instant::now();
    if sequential {
        let msa = MsaStage(RuntimeModel::load_subset(&dir, model.name, MSA_KINDS)?);
        let blk2 = Blk2Stage(RuntimeModel::load_subset(&dir, model.name, BLK2_KINDS)?);
        let (outs, wall) = run_sequential(model.depth, inputs, &msa, &blk2)?;
        let logits: Result<Vec<Tensor>> = outs.iter().map(|x| rt.head(x)).collect();
        let logits = logits?;
        println!(
            "sequential: {requests} requests in {wall:?} ({:.2} req/s)",
            requests as f64 / wall.as_secs_f64()
        );
        println!("first logits argmax: {}", logits[0].argmax());
    } else {
        let name = model.name;
        let dir_a = dir.clone();
        let dir_b = dir.clone();
        let (outs, report) = run_pipeline(
            model.depth,
            inputs,
            move || Ok(MsaStage(RuntimeModel::load_subset(&dir_a, name, MSA_KINDS)?)),
            move || Ok(Blk2Stage(RuntimeModel::load_subset(&dir_b, name, BLK2_KINDS)?)),
        )?;
        let logits: Result<Vec<Tensor>> = outs.iter().map(|x| rt.head(x)).collect();
        let logits = logits?;
        println!(
            "pipeline: {requests} requests in {:?} ({:.2} req/s), overlap {:.1}%",
            report.wall,
            requests as f64 / report.wall.as_secs_f64(),
            report.overlap_fraction * 100.0
        );
        println!("first logits argmax: {}", logits[0].argmax());
        println!("\nmeasured timeline:\n{}", report.timeline.render(100));
    }
    eprintln!("total wall (incl. head): {:?}", t1.elapsed());
    Ok(())
}

/// `serve`: HAS-choose a device design, then sweep a fleet of N
/// replicas over offered load on the discrete-event serving simulator
/// and print the latency–throughput curve.
fn cmd_serve(args: &[String]) -> Result<()> {
    use ubimoe::report::serving::{
        chaos_study, chaos_table, curve_table, fleet_curve, overload_study, overload_table,
        serving_study, shard_study, shard_table, DEFAULT_UTILS, SLO_FACTOR,
    };
    use ubimoe::serve::device::DeviceModel;
    use ubimoe::serve::dispatch::DispatchPolicy;

    let seconds: u64 = flag_value(args, "--seconds").unwrap_or("10").parse()?;
    let horizon = std::time::Duration::from_secs(seconds);
    if args.iter().any(|x| x == "--study") {
        // The full figure set: ZCU102 vs U280, 1–8 devices (two HAS
        // searches + 8 load sweeps — the expensive, complete version).
        // Platform/model/devices/policy are fixed by the study.
        for flag in ["--platform", "--model", "--devices", "--policy"] {
            if args.iter().any(|x| x == flag) {
                eprintln!("note: --study sweeps its own grid; {flag} is ignored");
            }
        }
        for t in serving_study(&[1, 2, 4, 8], horizon) {
            println!("{}", t.render());
        }
        return Ok(());
    }

    if args.iter().any(|x| x == "--faults") {
        // Chaos / fault-tolerance table on the HAS-chosen design: a
        // fixed 3-replica fleet under calibrated outages with
        // retries, hedging and autoscaled repair (see
        // `report::serving::chaos_study`). Honors --platform, --model
        // and --seconds; the fleet shape and policy grid are fixed by
        // the study.
        for flag in ["--devices", "--policy"] {
            if args.iter().any(|x| x == flag) {
                eprintln!("note: --faults runs a fixed scenario grid; {flag} is ignored");
            }
        }
        let platform = platform_arg(args)?;
        let model = model_arg(args, "m3vit-small")?;
        eprintln!("running HAS for the per-device design...");
        let device = DeviceModel::from_search(&model, &platform, 16, 32, &[1, 2, 4, 8]);
        eprintln!("injecting calibrated outages into a x3 {} fleet...", device.name);
        let t = chaos_table(&chaos_study(&device, model.num_experts, horizon * 3, 0xF1EE7));
        println!("{}", t.render());
        return Ok(());
    }

    if args.iter().any(|x| x == "--overload") {
        // Overload-protection table on the HAS-chosen design: a fixed
        // 3-replica fleet at 1.5x fleet peak, comparing no protection
        // (shadow), tiered admission + priority shedding, and
        // shedding + brownout degradation (see
        // `report::serving::overload_study`). Honors --platform,
        // --model and --seconds; the fleet shape and protection grid
        // are fixed by the study.
        for flag in ["--devices", "--policy"] {
            if args.iter().any(|x| x == flag) {
                eprintln!("note: --overload runs a fixed scenario grid; {flag} is ignored");
            }
        }
        let platform = platform_arg(args)?;
        let model = model_arg(args, "m3vit-small")?;
        eprintln!("running HAS for the per-device design...");
        let device = DeviceModel::from_search(&model, &platform, 16, 32, &[1, 2, 4, 8]);
        eprintln!("driving a x3 {} fleet at 1.5x fleet peak...", device.name);
        let study = overload_study(&device, model.num_experts, horizon * 3, 0xF1EE7);
        println!("{}", overload_table(&study).render());
        // Machine-greppable summary line (CI asserts shedding engaged
        // and brownout strictly reduced it at the interactive bar).
        let shed = study.row("admission+shedding");
        let brown = study.row("+brownout");
        println!(
            "overload: rejected={} brownout_rejected={} class0_attainment={:.4} \
             brownout_class0_attainment={:.4} degraded_completions={}",
            shed.rejected,
            brown.rejected,
            shed.class_attainment[0],
            brown.class_attainment[0],
            brown.degraded_completions
        );
        return Ok(());
    }

    if args.iter().any(|x| x == "--shard") {
        // Expert-sharding table on the HAS-chosen design: top-1 Zipf
        // routing over 8 experts, comparing replication factors
        // through a hot-expert home-device outage and static vs
        // rebalanced placement under popularity drift (see
        // `report::serving::shard_study`). Honors --platform, --model
        // and --seconds; fleet shapes and scenarios are fixed by the
        // study.
        for flag in ["--devices", "--policy"] {
            if args.iter().any(|x| x == flag) {
                eprintln!("note: --shard runs a fixed scenario grid; {flag} is ignored");
            }
        }
        let platform = platform_arg(args)?;
        let model = model_arg(args, "m3vit-small")?;
        eprintln!("running HAS for the per-device design...");
        let device = DeviceModel::from_search(&model, &platform, 16, 32, &[1, 2, 4, 8]);
        eprintln!("sharding 8 experts across {} fleets...", device.name);
        let study = shard_study(&device, horizon * 3, 0xF1EE7);
        println!("{}", shard_table(&study).render());
        // Machine-greppable summary line (CI asserts the replication
        // and rebalancing margins).
        let rf1 = study.row("rf=1 outage");
        let rf2 = study.row("rf=2 outage");
        let st = study.row("static drift");
        let rb = study.row("rebalanced drift");
        println!(
            "shard: rf1_goodput={:.4} rf2_goodput={:.4} rf1_no_replica={} \
             static_p99_ms={:.2} rebalanced_p99_ms={:.2} replica_adds={}",
            rf1.goodput, rf2.goodput, rf1.no_replica_drops, st.p99_ms, rb.p99_ms, rb.replica_adds
        );
        return Ok(());
    }

    let platform = platform_arg(args)?;
    let model = model_arg(args, "m3vit-small")?;
    let n: usize = flag_value(args, "--devices").unwrap_or("4").parse()?;
    let policy_name = flag_value(args, "--policy").unwrap_or("jsq");
    let policy = DispatchPolicy::by_name(policy_name)
        .with_context(|| format!("unknown policy {policy_name} (rr|wrr|jsq|affinity|sed)"))?;

    eprintln!("running HAS for the per-device design...");
    let device = DeviceModel::from_search(&model, &platform, 16, 32, &[1, 2, 4, 8]);
    println!(
        "device: {} — b1 latency {:.2} ms, peak {:.1} req/s, SLO {}x b1",
        device.name,
        device.unloaded_latency().as_secs_f64() * 1e3,
        device.peak_rps(),
        SLO_FACTOR,
    );

    // Observed single run: instead of the load sweep, simulate one
    // operating point with the tracer and/or sampler attached.
    let trace_path = flag_value(args, "--trace");
    let ts_path = flag_value(args, "--timeseries");
    if trace_path.is_some() || ts_path.is_some() {
        return serve_observed(args, trace_path, ts_path, &device, policy, model.num_experts, n, horizon);
    }

    eprintln!("sweeping {} load points concurrently...", DEFAULT_UTILS.len());
    let pts = fleet_curve(&device, n, policy, model.num_experts, DEFAULT_UTILS, horizon, 0xF1EE7);
    let title = format!(
        "Serving: {} x{n} fleet, {} ({} dispatch, {seconds}s horizon)",
        platform.name,
        model.name,
        policy.name()
    );
    println!("{}", curve_table(&title, &pts).render());
    Ok(())
}

/// One observed simulation run (`serve --trace F [--timeseries F]`):
/// fixed fleet at `--util` offered load, with the JSONL event tracer
/// and/or the windowed gauge sampler attached. Deterministic: the same
/// invocation writes byte-identical files (CI diffs two runs).
#[allow(clippy::too_many_arguments)]
fn serve_observed(
    args: &[String],
    trace_path: Option<&str>,
    ts_path: Option<&str>,
    device: &ubimoe::serve::device::DeviceModel,
    policy: ubimoe::serve::dispatch::DispatchPolicy,
    num_experts: usize,
    n: usize,
    horizon: std::time::Duration,
) -> Result<()> {
    use ubimoe::obs::{JsonlSink, Observer, SamplerConfig, TimeSeries, TraceSink};
    use ubimoe::report::serving::SLO_FACTOR;
    use ubimoe::serve::{
        simulate_fleet_observed, FaultConfig, FaultPlan, FaultSpan, ServeConfig, Workload,
    };

    let util: f64 = flag_value(args, "--util").unwrap_or("0.7").parse()?;
    let rate = util * device.peak_rps() * n as f64;
    let mut cfg = ServeConfig::uniform(device.clone(), n, Workload::Poisson { rate_rps: rate });
    cfg.dispatch = policy;
    cfg.num_experts = num_experts;
    cfg.horizon = horizon;
    let slo = device.unloaded_latency() * SLO_FACTOR;
    cfg.sampler = Some(SamplerConfig {
        slo: Some(slo),
        ..SamplerConfig::for_horizon(horizon, 200)
    });
    if args.iter().any(|x| x == "--inject-outage") {
        // Demo chaos for the analyzer's incident timeline: device 0
        // down for the second quarter of the horizon; its orphans fail
        // over to the rest of the fleet.
        let (from, to) = (horizon / 4, horizon / 2);
        eprintln!(
            "injecting scripted outage: device 0 down {:.2}s - {:.2}s",
            from.as_secs_f64(),
            to.as_secs_f64()
        );
        cfg.faults = Some(FaultConfig {
            plan: FaultPlan::new(vec![FaultSpan::new(0, from, to)]),
            ..FaultConfig::none()
        });
    }

    eprintln!(
        "simulating {} x{n} at {util:.2} fleet load ({rate:.1} req/s offered)...",
        device.name
    );
    let mut sink = match trace_path {
        Some(p) => Some(JsonlSink::create(p).with_context(|| format!("creating {p}"))?),
        None => None,
    };
    let mut series = TimeSeries::new();
    let report = simulate_fleet_observed(
        &cfg,
        Observer {
            trace: sink.as_mut().map(|s| s as &mut dyn TraceSink),
            series: ts_path.is_some().then_some(&mut series),
        },
    );

    println!(
        "observed   : {} admitted, {} completed, {} dropped over {:.1}s",
        report.admitted,
        report.fleet.completed,
        report.dropped,
        horizon.as_secs_f64()
    );
    println!(
        "e2e        : p50 {:.2} ms, p99 {:.2} ms; SLO({:.2} ms) attainment {:.1}%",
        report.fleet.e2e.p50().as_secs_f64() * 1e3,
        report.fleet.e2e.p99().as_secs_f64() * 1e3,
        slo.as_secs_f64() * 1e3,
        report.fleet.e2e.fraction_leq(slo) * 100.0
    );
    if let Some(sink) = sink {
        let records = sink.records();
        sink.finish().context("flushing trace file")?;
        println!("trace      : {records} records -> {}", trace_path.unwrap());
    }
    if let Some(p) = ts_path {
        std::fs::write(p, series.to_csv()).with_context(|| format!("writing {p}"))?;
        println!("timeseries : {} rows -> {p}", series.rows().len());
    }
    println!("work       : {}", ubimoe::obs::registry::snapshot().render());
    Ok(())
}

/// `trace analyze <file>`: reconstruct per-request spans from a JSONL
/// trace and print the latency breakdown + timelines.
fn cmd_trace(args: &[String]) -> Result<()> {
    const USAGE: &str = "usage: ubimoe trace analyze <trace.jsonl> [--slo-ms X] [--buckets N]";
    match args.first().map(|s| s.as_str()) {
        Some("analyze") => {
            let path = args
                .get(1)
                .filter(|p| !p.starts_with("--"))
                .with_context(|| USAGE.to_string())?;
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {path}"))?;
            let analysis = ubimoe::obs::analyze::analyze(&text)
                .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            let slo = flag_value(args, "--slo-ms")
                .map(|v| v.parse::<f64>())
                .transpose()
                .context("invalid --slo-ms value")?
                .map(|ms| std::time::Duration::from_secs_f64(ms / 1e3));
            let buckets: usize = flag_value(args, "--buckets").unwrap_or("72").parse()?;
            print!("{}", analysis.render(slo, buckets));
            Ok(())
        }
        _ => bail!("{USAGE}"),
    }
}

/// `deploy <file.ini>`: evaluate a deployment spec file (HAS unless
/// the spec pins an [override] configuration), printing the simulated
/// operating point.
fn cmd_deploy(args: &[String]) -> Result<()> {
    use ubimoe::config::DeploymentSpec;
    use ubimoe::has::{search, HasConfig};
    use ubimoe::sim::engine::{simulate, SimConfig};

    let path = args.first().context("usage: ubimoe deploy <spec.ini>")?;
    let spec = DeploymentSpec::load(std::path::Path::new(path))?;
    println!("deployment: {} on {} (W{}A{})",
        spec.model.name, spec.platform.name, spec.q_bits, spec.a_bits);

    let hw = match spec.hw_override {
        Some(hw) => {
            println!("configuration: {} (pinned by [override])", hw);
            hw
        }
        None => {
            let mut cfg = HasConfig::paper(spec.q_bits, spec.a_bits);
            cfg.ga = spec.ga;
            let r = search(&spec.model, &spec.platform, &cfg);
            println!("configuration: {} (HAS, {:?}, fit {:.3})", r.hw, r.stage, r.fit_score);
            r.hw
        }
    };
    let res = hw.resources(spec.model.heads, spec.model.patches, spec.model.dim);
    if !res.fits(&spec.platform.budget()) {
        bail!(
            "configuration does not fit {}: needs {:.0} DSP / {:.0} BRAM18, budget {:.0} / {:.0}",
            spec.platform.name,
            res.dsp,
            res.bram18,
            spec.platform.budget().dsp,
            spec.platform.budget().bram18
        );
    }
    let sim = simulate(&SimConfig::new(spec.model.clone(), spec.platform.clone(), hw));
    println!(
        "operating point: {:.2} ms/inf, {:.1} GOPS, {:.2} W, {:.3} GOPS/W",
        sim.latency_ms, sim.gops, sim.power_w, sim.gops_per_w
    );
    println!(
        "resources: {:.0} DSP, {:.0} BRAM18, {:.1}K LUT ({}% of DSP budget)",
        res.dsp,
        res.bram18,
        res.lut / 1e3,
        (100.0 * res.dsp / spec.platform.budget().dsp) as i64
    );
    Ok(())
}

/// `plan [--small]`: the fleet↔hardware co-design planner
/// ([`ubimoe::has::fleet`] + [`ubimoe::report::plan`]). Everything on
/// stdout is a pure function of the spec — cold and memo-warm runs are
/// byte-identical (CI `cmp`s them); the work-counter line goes to
/// stderr, where a warm run must show `des runs/events=0/0`.
fn cmd_plan(args: &[String]) -> Result<()> {
    use ubimoe::has::cache::{global_dir, DesignCache};
    use ubimoe::has::fleet::plan_fleet;
    use ubimoe::report::plan::{demo_spec, frontier_table, replay_table, small_spec};

    let spec = if args.iter().any(|x| x == "--small") { small_spec() } else { demo_spec() };
    let cache = match global_dir() {
        Some(d) => DesignCache::at(&d),
        None => DesignCache::disabled(),
    };
    eprintln!(
        "planning fleet '{}': {} templates, {} scenarios x {} policies, {} genomes...",
        spec.name,
        spec.templates.len(),
        spec.scenarios.len(),
        spec.policies.len(),
        spec.space_size()
    );
    let out = plan_fleet(&spec, &cache).map_err(|e| anyhow::anyhow!("invalid plan spec: {e}"))?;
    println!("{}", frontier_table(&spec, &out).render());
    println!(
        "plan: space={} evaluated={} feasible={} frontier={} mode={} ga_fitness_calls={}",
        out.space,
        out.evaluated,
        out.feasible,
        out.frontier.len(),
        if out.exhaustive { "exhaustive" } else { "ga" },
        out.ga_evaluations
    );
    println!("{}", replay_table(&cache, &spec, &out).render());
    eprintln!("work : {}", ubimoe::obs::registry::snapshot().render());
    Ok(())
}

/// `cache stats` / `cache gc --max-bytes N`: inspect and size-bound
/// the persistent design-artifact cache (the directory chosen by the
/// global `--design-cache` flag, default `.ubimoe-cache/`).
fn cmd_cache(args: &[String]) -> Result<()> {
    use ubimoe::has::cache::{global_dir, DesignCache};

    let Some(dir) = global_dir() else {
        bail!("design cache is disabled (--design-cache none) — nothing to inspect")
    };
    let cache = DesignCache::at(&dir);
    match args.first().map(|s| s.as_str()) {
        Some("stats") => {
            let s = cache.stats();
            println!("design cache : {}", dir.display());
            println!("artifacts    : {}", s.artifacts);
            println!("total bytes  : {} ({:.1} KiB)", s.total_bytes, s.total_bytes as f64 / 1024.0);
            if s.stale_tmp > 0 {
                println!("stale temps  : {} (run `ubimoe cache gc` to sweep)", s.stale_tmp);
            }
            // Process-wide work counters (obs::registry): how much
            // search/sim work this invocation actually performed —
            // all zeros on a fully warm cache.
            let w = ubimoe::obs::registry::snapshot();
            println!("work         : {}", w.render());
            println!("work json    : {}", w.to_json());
            Ok(())
        }
        Some("gc") => {
            let raw = flag_value(args, "--max-bytes")
                .context("usage: ubimoe cache gc --max-bytes N (suffixes k/m/g)")?;
            let max_bytes = parse_bytes(raw)
                .with_context(|| format!("invalid --max-bytes value {raw}"))?;
            let r = cache.gc(max_bytes);
            println!(
                "evicted {} of {} artifacts ({} bytes freed, {} kept); {} stale temp(s) swept",
                r.evicted, r.scanned, r.bytes_freed, r.bytes_kept, r.stale_tmp_removed
            );
            Ok(())
        }
        _ => bail!("usage: ubimoe cache <stats|gc --max-bytes N>"),
    }
}

/// Parse a byte count with an optional k/m/g (KiB/MiB/GiB) suffix.
fn parse_bytes(s: &str) -> Result<u64> {
    let s = s.trim().to_ascii_lowercase();
    let (num, mult) = match s.strip_suffix(['k', 'm', 'g']) {
        Some(num) => {
            let mult = match s.as_bytes()[s.len() - 1] {
                b'k' => 1u64 << 10,
                b'm' => 1u64 << 20,
                _ => 1u64 << 30,
            };
            (num, mult)
        }
        None => (s.as_str(), 1),
    };
    let n: u64 = num.parse()?;
    n.checked_mul(mult).context("byte count overflows u64")
}

fn cmd_info() -> Result<()> {
    let dir = ubimoe::runtime::artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    if !ubimoe::runtime::artifacts_available() {
        println!("  (not built — run `make artifacts`)");
        return Ok(());
    }
    let mut entries: Vec<_> = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "txt").unwrap_or(false))
        .collect();
    entries.sort();
    for p in entries {
        let len = std::fs::metadata(&p).map(|m| m.len()).unwrap_or(0);
        println!("  {:<48} {:>9} bytes", p.file_name().unwrap().to_string_lossy(), len);
    }
    Ok(())
}
