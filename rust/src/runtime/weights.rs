//! Weight loading: `<cfg>.weights.bin` + `.weights.manifest` →
//! named host tensors → device-resident PJRT buffers (loaded once at
//! startup, reused by every request — the runtime analog of expert
//! weights living in DDR/HBM).

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

use crate::runtime::meta::{load_manifest, DType};
use crate::runtime::tensor::Tensor;

/// All model parameters, by manifest name (e.g. "layers.3.moe.w1").
pub struct WeightStore {
    tensors: HashMap<String, Tensor>,
    /// Insertion order (manifest order) for deterministic iteration.
    order: Vec<String>,
}

impl WeightStore {
    pub fn load(bin_path: &Path, manifest_path: &Path) -> Result<WeightStore> {
        let raw = std::fs::read(bin_path)
            .with_context(|| format!("reading {}", bin_path.display()))?;
        let entries = load_manifest(manifest_path)?;
        let mut tensors = HashMap::new();
        let mut order = Vec::new();
        for e in entries {
            if e.spec.dtype != DType::F32 {
                bail!("weights must be f32, got {:?} for {}", e.spec.dtype, e.spec.name);
            }
            let nbytes = e.spec.elements() * 4;
            let end = e.offset + nbytes;
            if end > raw.len() {
                bail!(
                    "{}: range {}..{end} exceeds file ({} bytes)",
                    e.spec.name,
                    e.offset,
                    raw.len()
                );
            }
            let mut data = vec![0f32; e.spec.elements()];
            // Little-endian f32; x86-64/aarch64 both LE.
            for (i, chunk) in raw[e.offset..end].chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            order.push(e.spec.name.clone());
            tensors.insert(e.spec.name, Tensor::new(e.spec.dims, data));
        }
        Ok(WeightStore { tensors, order })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("missing weight {name} (have {} tensors)", self.order.len()))
    }

    pub fn names(&self) -> &[String] {
        &self.order
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Total parameter count (for reporting).
    pub fn total_params(&self) -> usize {
        self.tensors.values().map(|t| t.len()).sum()
    }
}

/// Device-resident copies of a weight subset, keyed by name.
pub struct DeviceWeights {
    buffers: HashMap<String, xla::PjRtBuffer>,
}

impl DeviceWeights {
    /// Upload the named tensors once.
    pub fn upload(
        client: &xla::PjRtClient,
        store: &WeightStore,
        names: &[String],
    ) -> Result<DeviceWeights> {
        let mut buffers = HashMap::new();
        for n in names {
            let t = store.get(n)?;
            let buf = client.buffer_from_host_buffer(&t.data, &t.dims, None)?;
            buffers.insert(n.clone(), buf);
        }
        Ok(DeviceWeights { buffers })
    }

    pub fn get(&self, name: &str) -> Result<&xla::PjRtBuffer> {
        self.buffers
            .get(name)
            .with_context(|| format!("weight {name} not uploaded"))
    }

    pub fn len(&self) -> usize {
        self.buffers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buffers.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_fixture(dir: &Path) -> (std::path::PathBuf, std::path::PathBuf) {
        let bin = dir.join("w.bin");
        let man = dir.join("w.manifest");
        let data: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let mut f = std::fs::File::create(&bin).unwrap();
        for x in &data {
            f.write_all(&x.to_le_bytes()).unwrap();
        }
        std::fs::write(&man, "a:float32:2,3:0\nb:float32:4:24\n").unwrap();
        (bin, man)
    }

    #[test]
    fn loads_by_offset() {
        let dir = std::env::temp_dir().join("ubimoe_wtest");
        std::fs::create_dir_all(&dir).unwrap();
        let (bin, man) = write_fixture(&dir);
        let ws = WeightStore::load(&bin, &man).unwrap();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws.get("a").unwrap().dims, vec![2, 3]);
        assert_eq!(ws.get("a").unwrap().data, vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(ws.get("b").unwrap().data, vec![6., 7., 8., 9.]);
        assert_eq!(ws.total_params(), 10);
        assert!(ws.get("missing").is_err());
    }

    #[test]
    fn rejects_out_of_range() {
        let dir = std::env::temp_dir().join("ubimoe_wtest2");
        std::fs::create_dir_all(&dir).unwrap();
        let (bin, man) = write_fixture(&dir);
        std::fs::write(&man, "a:float32:100:0\n").unwrap();
        assert!(WeightStore::load(&bin, &man).is_err());
    }
}
