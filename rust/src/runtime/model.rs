//! RuntimeModel: the full set of compiled blocks + weights for one
//! model configuration. This is what the coordinator drives.
//!
//! A RuntimeModel owns its PJRT client (the `xla` handle is not Send),
//! so one instance lives entirely on one thread. The pipeline loads a
//! *subset* model per engine thread (see `load_subset`).

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

use crate::models::{by_name, ModelConfig};
use crate::runtime::executable::{
    literal_to_tensor, literal_to_tensor_i32, tensor_to_literal, BlockExecutable,
};
use crate::runtime::tensor::{Tensor, TensorI32};
use crate::runtime::weights::{DeviceWeights, WeightStore};

/// Block kinds emitted by aot.py. `full_model` (the monolithic
/// ablation) is excluded from the default load: it is by far the most
/// expensive compile and only forward_monolithic needs it.
pub const BLOCK_KINDS: &[&str] =
    &["msa_block", "dense_ffn", "moe_block", "gate_probe", "patch_embed", "head"];

/// Everything, including the monolithic executable.
pub const ALL_KINDS: &[&str] =
    &["msa_block", "dense_ffn", "moe_block", "gate_probe", "patch_embed", "head", "full_model"];

/// Kinds needed by the MSA engine thread.
pub const MSA_KINDS: &[&str] = &["msa_block"];
/// Kinds needed by the FFN/MoE engine thread.
pub const BLK2_KINDS: &[&str] = &["dense_ffn", "moe_block"];
/// Kinds needed by the host (non-encoder) side.
pub const HOST_KINDS: &[&str] = &["patch_embed", "head", "gate_probe"];

pub struct RuntimeModel {
    pub cfg: ModelConfig,
    client: xla::PjRtClient,
    /// (kind, batch) → compiled executable.
    blocks: HashMap<(String, usize), BlockExecutable>,
    pub weights: WeightStore,
    device: DeviceWeights,
    batches: Vec<usize>,
}

impl RuntimeModel {
    /// Load every artifact for `cfg_name` found in `dir`.
    pub fn load(dir: &Path, cfg_name: &str) -> Result<RuntimeModel> {
        Self::load_subset(dir, cfg_name, BLOCK_KINDS)
    }

    /// Load only the given block kinds (per-engine views).
    pub fn load_subset(dir: &Path, cfg_name: &str, kinds: &[&str]) -> Result<RuntimeModel> {
        let cfg =
            by_name(cfg_name).with_context(|| format!("unknown model config {cfg_name}"))?;
        let client = crate::runtime::new_client()?;
        let weights = WeightStore::load(
            &dir.join(format!("{cfg_name}.weights.bin")),
            &dir.join(format!("{cfg_name}.weights.manifest")),
        )?;

        let mut blocks = HashMap::new();
        let mut batches: Vec<usize> = Vec::new();
        for kind in kinds {
            for b in [1usize, 2, 4, 8, 16] {
                let base = dir.join(format!("{cfg_name}.{kind}.b{b}"));
                if std::path::Path::new(&format!("{}.hlo.txt", base.display())).exists() {
                    let exe = BlockExecutable::load(&client, &base)
                        .with_context(|| format!("loading {kind} b{b}"))?;
                    blocks.insert((kind.to_string(), b), exe);
                    if !batches.contains(&b) {
                        batches.push(b);
                    }
                }
            }
        }
        if blocks.is_empty() {
            bail!("no artifacts for {cfg_name} ({kinds:?}) under {}", dir.display());
        }
        batches.sort_unstable();

        // Upload only the weights the loaded blocks reference.
        let mut needed: Vec<String> = Vec::new();
        for ((kind, _), exe) in &blocks {
            if kind == "full_model" {
                needed = weights.names().to_vec();
                break;
            }
            for layer in 0..cfg.depth {
                if Self::kind_active_at(&cfg, kind, layer) {
                    let prefix = Self::prefix_for(kind, layer);
                    for spec in &exe.meta.inputs[1..] {
                        let name = format!("{prefix}{}", spec.name);
                        if !needed.contains(&name) {
                            needed.push(name);
                        }
                    }
                }
                if kind == "patch_embed" || kind == "head" {
                    break; // layer-independent
                }
            }
        }
        let device = DeviceWeights::upload(&client, &weights, &needed)?;

        Ok(RuntimeModel { cfg, client, blocks, weights, device, batches })
    }

    fn kind_active_at(cfg: &ModelConfig, kind: &str, layer: usize) -> bool {
        match kind {
            "msa_block" => true,
            "moe_block" | "gate_probe" => cfg.is_moe_layer(layer),
            "dense_ffn" => !cfg.is_moe_layer(layer),
            "patch_embed" | "head" => layer == 0,
            _ => false,
        }
    }

    pub fn batches(&self) -> &[usize] {
        &self.batches
    }

    pub fn has_block(&self, kind: &str, batch: usize) -> bool {
        self.blocks.contains_key(&(kind.to_string(), batch))
    }

    fn block(&self, kind: &str, batch: usize) -> Result<&BlockExecutable> {
        self.blocks
            .get(&(kind.to_string(), batch))
            .with_context(|| format!("no artifact {kind} for batch {batch}"))
    }

    /// Weight-name prefix feeding a block at a given layer.
    fn prefix_for(kind: &str, layer: usize) -> String {
        match kind {
            "msa_block" => format!("layers.{layer}.msa."),
            "moe_block" | "gate_probe" => format!("layers.{layer}.moe."),
            "dense_ffn" => format!("layers.{layer}.ffn."),
            "patch_embed" => "embed.".into(),
            "head" => "head.".into(),
            other => panic!("no weight prefix for {other}"),
        }
    }

    /// Execute one block: `x` plus this layer's weights (device-
    /// resident), returning all outputs as literals.
    fn run_block_raw(&self, kind: &str, layer: usize, x: &Tensor) -> Result<Vec<xla::Literal>> {
        let batch = x.dims[0];
        let exe = self.block(kind, batch)?;
        let prefix = Self::prefix_for(kind, layer);
        let x_buf = self.client.buffer_from_host_buffer(&x.data, &x.dims, None)?;
        let mut bufs: Vec<&xla::PjRtBuffer> = vec![&x_buf];
        for spec in &exe.meta.inputs[1..] {
            bufs.push(self.device.get(&format!("{prefix}{}", spec.name))?);
        }
        exe.run_buffers(&bufs)
    }

    pub fn msa(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let out = self.run_block_raw("msa_block", layer, x)?;
        literal_to_tensor(&out[0])
    }

    /// The second encoder half for `layer` (dense FFN or MoE, per cfg).
    pub fn ffn_or_moe(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let kind = if self.cfg.is_moe_layer(layer) { "moe_block" } else { "dense_ffn" };
        let out = self.run_block_raw(kind, layer, x)?;
        literal_to_tensor(&out[0])
    }

    /// Gate decisions for a MoE layer: (weights (B,N,k), indices).
    pub fn gate(&self, layer: usize, x: &Tensor) -> Result<(Tensor, TensorI32)> {
        if !self.cfg.is_moe_layer(layer) {
            bail!("layer {layer} is not a MoE layer");
        }
        let out = self.run_block_raw("gate_probe", layer, x)?;
        Ok((literal_to_tensor(&out[0])?, literal_to_tensor_i32(&out[1])?))
    }

    pub fn embed(&self, imgs: &Tensor) -> Result<Tensor> {
        let out = self.run_block_raw("patch_embed", 0, imgs)?;
        literal_to_tensor(&out[0])
    }

    pub fn head(&self, x: &Tensor) -> Result<Tensor> {
        let out = self.run_block_raw("head", 0, x)?;
        literal_to_tensor(&out[0])
    }

    /// Sequential whole-model forward (reference path; the coordinator
    /// pipeline is the performant path).
    pub fn forward(&self, imgs: &Tensor) -> Result<Tensor> {
        let mut x = self.embed(imgs)?;
        for layer in 0..self.cfg.depth {
            x = self.msa(layer, &x)?;
            x = self.ffn_or_moe(layer, &x)?;
        }
        self.head(&x)
    }

    /// Monolithic single-executable forward (ablation vs the block
    /// pipeline): feeds the image plus every weight in manifest order.
    pub fn forward_monolithic(&self, imgs: &Tensor) -> Result<Tensor> {
        let batch = imgs.dims[0];
        let exe = self.block("full_model", batch)?;
        let img_buf = self.client.buffer_from_host_buffer(&imgs.data, &imgs.dims, None)?;
        let mut bufs: Vec<&xla::PjRtBuffer> = vec![&img_buf];
        for name in self.weights.names() {
            bufs.push(self.device.get(name)?);
        }
        let out = exe.run_buffers(&bufs)?;
        literal_to_tensor(&out[0])
    }

    /// Per-expert token histogram from real gate indices — feeds the
    /// simulator with measured routing instead of synthetic balance.
    pub fn histogram(&self, gate_idx: &TensorI32) -> Vec<usize> {
        let mut h = vec![0usize; self.cfg.num_experts];
        for &e in &gate_idx.data {
            if (e as usize) < h.len() {
                h[e as usize] += 1;
            }
        }
        h
    }

    /// Run the MSA block via host literals (slow path; kept for parity
    /// tests against the device-buffer path).
    pub fn msa_via_literals(&self, layer: usize, x: &Tensor) -> Result<Tensor> {
        let exe = self.block("msa_block", x.dims[0])?;
        let prefix = Self::prefix_for("msa_block", layer);
        let mut lits = vec![tensor_to_literal(x)?];
        for spec in &exe.meta.inputs[1..] {
            lits.push(tensor_to_literal(self.weights.get(&format!("{prefix}{}", spec.name))?)?);
        }
        exe.run_f32(&lits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_mapping() {
        assert_eq!(RuntimeModel::prefix_for("msa_block", 3), "layers.3.msa.");
        assert_eq!(RuntimeModel::prefix_for("moe_block", 1), "layers.1.moe.");
        assert_eq!(RuntimeModel::prefix_for("dense_ffn", 0), "layers.0.ffn.");
        assert_eq!(RuntimeModel::prefix_for("patch_embed", 0), "embed.");
    }

    #[test]
    fn kind_active_logic() {
        let cfg = crate::models::m3vit_tiny();
        assert!(RuntimeModel::kind_active_at(&cfg, "moe_block", 1));
        assert!(!RuntimeModel::kind_active_at(&cfg, "moe_block", 0));
        assert!(RuntimeModel::kind_active_at(&cfg, "dense_ffn", 0));
        assert!(RuntimeModel::kind_active_at(&cfg, "msa_block", 5));
    }
}
