//! PJRT executable wrapper: load an HLO-text artifact + its metadata,
//! compile on the CPU client, execute with host tensors.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

use crate::runtime::meta::{BlockMeta, DType};
use crate::runtime::tensor::{Tensor, TensorI32};

/// Convert a host tensor to an xla literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Convert an f32 literal back to a host tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::new(dims, data))
}

/// Convert an i32 literal (gate indices) to a host tensor.
pub fn literal_to_tensor_i32(lit: &xla::Literal) -> Result<TensorI32> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<i32>()?;
    Ok(TensorI32::new(dims, data))
}

/// One compiled model block (MSA, MoE, dense FFN, embed, head, …).
pub struct BlockExecutable {
    pub meta: BlockMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl BlockExecutable {
    /// Load `<base>.hlo.txt` + `<base>.meta` and compile. (`base` may
    /// contain dots — e.g. `m3vit-tiny.msa_block.b1` — so extensions
    /// are appended, not substituted.)
    pub fn load(client: &xla::PjRtClient, base: &Path) -> Result<BlockExecutable> {
        let hlo = PathBuf::from(format!("{}.hlo.txt", base.display()));
        let meta = BlockMeta::load(&PathBuf::from(format!("{}.meta", base.display())))?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", hlo.display()))?;
        Ok(BlockExecutable { meta, exe })
    }

    /// Execute with literal inputs; returns the unwrapped tuple of
    /// output literals (aot.py lowers with return_tuple=True).
    pub fn run_literals(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            bail!(
                "{}: {} inputs given, {} expected",
                self.meta.name,
                inputs.len(),
                self.meta.inputs.len()
            );
        }
        let out = self.exe.execute::<xla::Literal>(inputs)?;
        let lit = out[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: {} outputs returned, {} expected",
                self.meta.name,
                parts.len(),
                self.meta.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Execute with device-resident buffers (hot path: weights stay on
    /// device; only activations cross the host boundary).
    pub fn run_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::PjRtBuffer> = inputs.to_vec();
        let out = self.exe.execute_b(&refs)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with device buffers, returning output buffers without
    /// host transfer (for chaining; PJRT CPU keeps them zero-copy).
    pub fn run_buffers_to_buffers(
        &self,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let refs: Vec<&xla::PjRtBuffer> = inputs.to_vec();
        let mut out = self.exe.execute_b(&refs)?;
        Ok(std::mem::take(&mut out[0]))
    }

    /// Typed convenience: single-f32-output blocks (msa/moe/ffn/...).
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Tensor> {
        let parts = self.run_literals(inputs)?;
        if self.meta.outputs[0].dtype != DType::F32 {
            bail!("{}: first output is not f32", self.meta.name);
        }
        literal_to_tensor(&parts[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::random(vec![2, 3, 4], 1.0, 3);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_shape_preserved() {
        let t = Tensor::zeros(vec![5, 7]);
        let lit = tensor_to_literal(&t).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[5, 7]);
    }
}
