//! Host-side tensor: a dense row-major f32 (or i32) array with shape.
//! The minimal data type the coordinator moves between PJRT
//! executables; conversion to/from `xla::Literal` lives in
//! runtime/executable.rs.

/// Dense row-major tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            dims.iter().product::<usize>(),
            data.len(),
            "shape {dims:?} vs {} elements",
            data.len()
        );
        Tensor { dims, data }
    }

    pub fn zeros(dims: Vec<usize>) -> Tensor {
        let n = dims.iter().product();
        Tensor { dims, data: vec![0.0; n] }
    }

    /// Seeded random tensor in [-scale, scale] (synthetic workloads).
    pub fn random(dims: Vec<usize>, scale: f32, seed: u64) -> Tensor {
        let mut rng = crate::util::rng::Rng::new(seed);
        let n = dims.iter().product();
        let data = (0..n).map(|_| rng.f32_range(-scale, scale)).collect();
        Tensor { dims, data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Strict reshape (element count preserved).
    pub fn reshape(mut self, dims: Vec<usize>) -> Tensor {
        assert_eq!(dims.iter().product::<usize>(), self.data.len());
        self.dims = dims;
        self
    }

    /// Slice the leading (batch) dimension: rows [start, start+len).
    pub fn slice_batch(&self, start: usize, len: usize) -> Tensor {
        assert!(self.rank() >= 1);
        let b = self.dims[0];
        assert!(start + len <= b, "slice {start}+{len} > batch {b}");
        let row: usize = self.dims[1..].iter().product();
        let mut dims = self.dims.clone();
        dims[0] = len;
        Tensor::new(dims, self.data[start * row..(start + len) * row].to_vec())
    }

    /// Stack tensors along a new/existing leading batch dimension.
    /// All inputs must share trailing dims; batch sizes may differ.
    pub fn cat_batch(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let trailing = &parts[0].dims[1..];
        let mut data = Vec::new();
        let mut batch = 0;
        for p in parts {
            assert_eq!(&p.dims[1..], trailing, "trailing dims differ");
            batch += p.dims[0];
            data.extend_from_slice(&p.data);
        }
        let mut dims = vec![batch];
        dims.extend_from_slice(trailing);
        Tensor::new(dims, data)
    }

    /// Pad the batch dimension up to `batch` by repeating the last row.
    pub fn pad_batch_to(&self, batch: usize) -> Tensor {
        let b = self.dims[0];
        assert!(b > 0 && b <= batch);
        if b == batch {
            return self.clone();
        }
        let row: usize = self.dims[1..].iter().product();
        let mut data = self.data.clone();
        let last = self.data[(b - 1) * row..b * row].to_vec();
        for _ in b..batch {
            data.extend_from_slice(&last);
        }
        let mut dims = self.dims.clone();
        dims[0] = batch;
        Tensor::new(dims, data)
    }

    /// Max |a-b| against another tensor (validation).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.dims, other.dims);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Integer tensor (gate indices).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub dims: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn new(dims: Vec<usize>, data: Vec<i32>) -> TensorI32 {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        TensorI32 { dims, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_shape() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.rank(), 2);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn new_rejects_mismatch() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn slice_and_cat_roundtrip() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let a = t.slice_batch(0, 1);
        let b = t.slice_batch(1, 2);
        assert_eq!(a.data, vec![1., 2.]);
        assert_eq!(b.data, vec![3., 4., 5., 6.]);
        let back = Tensor::cat_batch(&[a, b]);
        assert_eq!(back, t);
    }

    #[test]
    fn pad_batch_repeats_last() {
        let t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let p = t.pad_batch_to(4);
        assert_eq!(p.dims, vec![4, 2]);
        assert_eq!(&p.data[4..], &[3., 4., 3., 4.]);
        // exact size is a no-op clone
        assert_eq!(t.pad_batch_to(2), t);
    }

    #[test]
    fn random_is_deterministic() {
        let a = Tensor::random(vec![4, 4], 1.0, 7);
        let b = Tensor::random(vec![4, 4], 1.0, 7);
        assert_eq!(a, b);
        let c = Tensor::random(vec![4, 4], 1.0, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn argmax_and_diff() {
        let a = Tensor::new(vec![4], vec![0.0, 3.0, 2.0, -1.0]);
        assert_eq!(a.argmax(), 1);
        let b = Tensor::new(vec![4], vec![0.5, 3.0, 2.0, -1.0]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-7);
    }
}
