//! Artifact metadata parsing: the `.meta`, `.weights.manifest` and
//! `.golden.meta` sidecars aot.py writes (simple line-based `k=v` /
//! colon-separated formats — the vendored crate set has no serde).

use anyhow::{bail, Context, Result};
use std::path::Path;

/// Element type of a tensor in an artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" | "f32" => DType::F32,
            "int32" | "i32" => DType::I32,
            other => bail!("unsupported dtype {other}"),
        })
    }

    pub fn size(&self) -> usize {
        4
    }
}

/// One named tensor slot (executable input/output or weight entry).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }

    /// Parse `name:dtype:1,2,3` (dims may be empty for scalars).
    fn parse(s: &str) -> Result<TensorSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        if parts.len() < 3 {
            bail!("bad tensor spec {s:?}");
        }
        let dims = if parts[2].is_empty() {
            vec![]
        } else {
            parts[2]
                .split(',')
                .map(|d| d.parse::<usize>().context("dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(TensorSpec { name: parts[0].to_string(), dtype: DType::parse(parts[1])?, dims })
    }
}

/// Parsed `.meta` sidecar of one HLO artifact.
#[derive(Clone, Debug)]
pub struct BlockMeta {
    pub name: String,
    pub config: String,
    pub batch: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl BlockMeta {
    pub fn parse(text: &str) -> Result<BlockMeta> {
        let mut name = String::new();
        let mut config = String::new();
        let mut batch = 0usize;
        let mut inputs = Vec::new();
        let mut outputs = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: no '='", lineno + 1))?;
            match k {
                "name" => name = v.to_string(),
                "config" => config = v.to_string(),
                "batch" => batch = v.parse()?,
                "input" => inputs.push(TensorSpec::parse(v)?),
                "output" => outputs.push(TensorSpec::parse(v)?),
                other => bail!("line {}: unknown key {other}", lineno + 1),
            }
        }
        if name.is_empty() || inputs.is_empty() || outputs.is_empty() {
            bail!("incomplete meta (name={name:?}, {} in, {} out)", inputs.len(), outputs.len());
        }
        Ok(BlockMeta { name, config, batch, inputs, outputs })
    }

    pub fn load(path: &Path) -> Result<BlockMeta> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }
}

/// One entry of `.weights.manifest` / `.golden.meta`:
/// `name:dtype:dims:byte_offset`.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub spec: TensorSpec,
    pub offset: usize,
}

/// Parse a whole manifest file.
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (head, off) = line
            .rsplit_once(':')
            .with_context(|| format!("line {}: no offset", lineno + 1))?;
        out.push(ManifestEntry {
            spec: TensorSpec::parse(head)?,
            offset: off.parse().with_context(|| format!("line {}", lineno + 1))?,
        });
    }
    Ok(out)
}

pub fn load_manifest(path: &Path) -> Result<Vec<ManifestEntry>> {
    parse_manifest(
        &std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = "\
name=msa_block
config=m3vit-tiny
batch=1
input=x:float32:1,65,192
input=ln_g:float32:192
output=y:float32:1,65,192
";

    #[test]
    fn parses_meta() {
        let m = BlockMeta::parse(META).unwrap();
        assert_eq!(m.name, "msa_block");
        assert_eq!(m.batch, 1);
        assert_eq!(m.inputs.len(), 2);
        assert_eq!(m.inputs[0].dims, vec![1, 65, 192]);
        assert_eq!(m.inputs[1].dims, vec![192]);
        assert_eq!(m.outputs[0].elements(), 65 * 192);
    }

    #[test]
    fn rejects_incomplete() {
        assert!(BlockMeta::parse("name=x\n").is_err());
        assert!(BlockMeta::parse("nonsense").is_err());
        assert!(BlockMeta::parse("name=x\nbogus=1\n").is_err());
    }

    #[test]
    fn parses_manifest_lines() {
        let m = parse_manifest(
            "embed.w:float32:192,576:0\nembed.b:float32:576:442368\n",
        )
        .unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].spec.name, "embed.w");
        assert_eq!(m[1].offset, 442_368);
        assert_eq!(m[0].spec.elements(), 192 * 576);
    }

    #[test]
    fn parses_int32_dtype() {
        let m = BlockMeta::parse(
            "name=gate_probe\nconfig=c\nbatch=1\ninput=x:float32:1,4\noutput=gi:int32:1,4,2\n",
        )
        .unwrap();
        assert_eq!(m.outputs[0].dtype, DType::I32);
    }

    #[test]
    fn scalar_dims_allowed() {
        let t = TensorSpec::parse("s:float32:").unwrap();
        assert_eq!(t.dims, Vec::<usize>::new());
        assert_eq!(t.elements(), 1);
    }
}
