//! L3 runtime: loads the AOT artifacts (HLO text + weights + metadata)
//! and executes them on the PJRT CPU client. Python never runs here —
//! after `make artifacts` the Rust binary is self-contained.
//!
//! Threading note: the `xla` crate's client handle is `Rc`-based (not
//! `Send`), so every engine thread constructs its *own* client and
//! compiles its own blocks — which mirrors the hardware, where the MSA
//! and MoE blocks are physically separate fabric regions with their own
//! configuration. See coordinator/pipeline.rs.

pub mod executable;
pub mod golden;
pub mod meta;
pub mod model;
pub mod tensor;
pub mod weights;

use anyhow::{Context, Result};
use std::path::PathBuf;

/// Create a PJRT CPU client (one per engine/thread).
pub fn new_client() -> Result<xla::PjRtClient> {
    xla::PjRtClient::cpu().context("creating PJRT CPU client")
}

/// Locate the artifacts directory: $UBIMOE_ARTIFACTS or ./artifacts
/// walking up from the current directory.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("UBIMOE_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("STAMP").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// True when artifacts exist (integration tests skip gracefully when
/// `make artifacts` has not run).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("STAMP").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves_something() {
        let d = artifacts_dir();
        assert!(d.to_string_lossy().contains("artifacts"));
    }

    #[test]
    fn client_creation_works() {
        // Requires libxla_extension at runtime — present in this image.
        let c = new_client().unwrap();
        assert!(c.device_count() >= 1);
    }
}
