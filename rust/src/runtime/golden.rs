//! Golden reference loading: `<cfg>.golden.bin` + `.golden.meta`
//! written by aot.py hold a seeded input batch plus the JAX-computed
//! activations after every layer — the ground truth the Rust pipeline
//! must reproduce bit-closely (integration tests + e2e example).

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

use crate::runtime::meta::load_manifest;
use crate::runtime::tensor::Tensor;

pub struct Golden {
    tensors: HashMap<String, Tensor>,
}

impl Golden {
    pub fn load(dir: &Path, cfg_name: &str) -> Result<Golden> {
        let bin = std::fs::read(dir.join(format!("{cfg_name}.golden.bin")))
            .with_context(|| format!("golden bin for {cfg_name}"))?;
        let entries = load_manifest(&dir.join(format!("{cfg_name}.golden.meta")))?;
        let mut tensors = HashMap::new();
        for e in entries {
            let nbytes = e.spec.elements() * 4;
            if e.offset + nbytes > bin.len() {
                bail!("golden {} out of range", e.spec.name);
            }
            let data: Vec<f32> = bin[e.offset..e.offset + nbytes]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            tensors.insert(e.spec.name, Tensor::new(e.spec.dims, data));
        }
        Ok(Golden { tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors.get(name).with_context(|| format!("no golden tensor {name}"))
    }

    pub fn input(&self) -> Result<&Tensor> {
        self.get("input")
    }

    pub fn logits(&self) -> Result<&Tensor> {
        self.get("logits")
    }

    pub fn layer(&self, i: usize) -> Result<&Tensor> {
        self.get(&format!("layer{i}"))
    }

    pub fn names(&self) -> Vec<&String> {
        let mut v: Vec<&String> = self.tensors.keys().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{artifacts_available, artifacts_dir};

    #[test]
    fn golden_loads_when_artifacts_present() {
        if !artifacts_available() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let g = Golden::load(&artifacts_dir(), "m3vit-tiny").unwrap();
        let input = g.input().unwrap();
        assert_eq!(input.dims, vec![4, 3, 64, 64]);
        let logits = g.logits().unwrap();
        assert_eq!(logits.dims, vec![4, 10]);
        assert!(g.layer(0).is_ok() && g.layer(5).is_ok());
        assert!(g.get("embed").is_ok());
    }
}
