//! Generic integer-genome genetic algorithm ("traditional GA",
//! Algorithm 1 line 8): tournament selection, uniform crossover,
//! per-gene mutation, elitism. Deterministic given the seed.

use crate::util::rng::Rng;

/// GA hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct GaParams {
    pub population: usize,
    pub generations: usize,
    pub tournament: usize,
    pub crossover_p: f64,
    pub mutation_p: f64,
    pub elites: usize,
    pub seed: u64,
}

impl Default for GaParams {
    fn default() -> Self {
        GaParams {
            population: 48,
            generations: 60,
            tournament: 3,
            crossover_p: 0.9,
            mutation_p: 0.15,
            elites: 2,
            seed: 0xC0FFEE,
        }
    }
}

/// Problem definition: genome length, per-gene cardinality, fitness
/// (higher is better). Infeasible individuals should return f64::MIN
/// or a strongly penalized score.
pub trait GaProblem {
    fn genes(&self) -> usize;
    fn gene_len(&self, gene: usize) -> usize;
    fn fitness(&self, genome: &[usize]) -> f64;
}

/// Result of a GA run.
#[derive(Clone, Debug)]
pub struct GaOutcome {
    pub best_genome: Vec<usize>,
    pub best_fitness: f64,
    /// Best fitness per generation (convergence curve).
    pub history: Vec<f64>,
    /// `fitness()` invocations: initial population plus the newly bred
    /// individuals each generation. Elites carry their scores forward
    /// (fitness is deterministic), so they are never re-evaluated.
    pub evaluations: usize,
}

pub fn run<P: GaProblem>(problem: &P, params: &GaParams) -> GaOutcome {
    let mut rng = Rng::new(params.seed);
    let genes = problem.genes();
    let pop_n = params.population.max(2);

    let random_genome = |rng: &mut Rng| -> Vec<usize> {
        (0..genes).map(|g| rng.below(problem.gene_len(g))).collect()
    };

    let mut pop: Vec<Vec<usize>> = (0..pop_n).map(|_| random_genome(&mut rng)).collect();
    let mut fit: Vec<f64> = pop.iter().map(|g| problem.fitness(g)).collect();
    let mut evaluations = pop_n;
    let mut history = Vec::with_capacity(params.generations);

    for _gen in 0..params.generations {
        // Track elites.
        let mut order: Vec<usize> = (0..pop_n).collect();
        order.sort_by(|&a, &b| fit[b].total_cmp(&fit[a]));
        history.push(fit[order[0]]);

        let tournament = |rng: &mut Rng| -> usize {
            let mut best = rng.below(pop_n);
            for _ in 1..params.tournament {
                let c = rng.below(pop_n);
                if fit[c] > fit[best] {
                    best = c;
                }
            }
            best
        };

        let n_elites = params.elites.min(pop_n);
        let mut next: Vec<Vec<usize>> = Vec::with_capacity(pop_n);
        // Elites carry genome AND score into the next generation —
        // fitness is deterministic, so re-scoring them every
        // generation (as the seed did) was pure waste.
        let mut next_fit: Vec<f64> = Vec::with_capacity(pop_n);
        for &e in order.iter().take(n_elites) {
            next.push(pop[e].clone());
            next_fit.push(fit[e]);
        }
        while next.len() < pop_n {
            let a = tournament(&mut rng);
            let b = tournament(&mut rng);
            let mut child = if rng.chance(params.crossover_p) {
                // uniform crossover
                (0..genes)
                    .map(|g| if rng.bool_gene() { pop[a][g] } else { pop[b][g] })
                    .collect::<Vec<_>>()
            } else {
                pop[a].clone()
            };
            for (g, slot) in child.iter_mut().enumerate() {
                if rng.chance(params.mutation_p) {
                    *slot = rng.below(problem.gene_len(g));
                }
            }
            next.push(child);
        }
        // Score only the newly bred individuals.
        for g in next.iter().skip(n_elites) {
            next_fit.push(problem.fitness(g));
        }
        evaluations += pop_n - n_elites;
        pop = next;
        fit = next_fit;
    }

    let (best_i, _) = fit
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .expect("non-empty population");
    GaOutcome {
        best_genome: pop[best_i].clone(),
        best_fitness: fit[best_i],
        history,
        evaluations,
    }
}

trait BoolGene {
    fn bool_gene(&mut self) -> bool;
}

impl BoolGene for Rng {
    fn bool_gene(&mut self) -> bool {
        self.chance(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Max-sum toy problem: fitness = Σ genome[i]; optimum is all-max.
    struct MaxSum {
        lens: Vec<usize>,
    }

    impl GaProblem for MaxSum {
        fn genes(&self) -> usize {
            self.lens.len()
        }
        fn gene_len(&self, g: usize) -> usize {
            self.lens[g]
        }
        fn fitness(&self, genome: &[usize]) -> f64 {
            genome.iter().map(|&x| x as f64).sum()
        }
    }

    #[test]
    fn finds_trivial_optimum() {
        let p = MaxSum { lens: vec![8; 6] };
        let out = run(&p, &GaParams { generations: 40, ..Default::default() });
        assert_eq!(out.best_genome, vec![7; 6], "fitness {}", out.best_fitness);
    }

    #[test]
    fn deterministic_for_seed() {
        let p = MaxSum { lens: vec![10; 4] };
        let a = run(&p, &GaParams::default());
        let b = run(&p, &GaParams::default());
        assert_eq!(a.best_genome, b.best_genome);
        assert_eq!(a.history, b.history);
    }

    #[test]
    fn history_is_monotone_with_elitism() {
        let p = MaxSum { lens: vec![12; 5] };
        let out = run(&p, &GaParams::default());
        for w in out.history.windows(2) {
            assert!(w[1] >= w[0], "elitism must keep the best: {:?}", out.history);
        }
    }

    /// Deceptive problem: a narrow spike the GA must still find often.
    struct Spike;
    impl GaProblem for Spike {
        fn genes(&self) -> usize {
            3
        }
        fn gene_len(&self, _: usize) -> usize {
            16
        }
        fn fitness(&self, g: &[usize]) -> f64 {
            if g == [3, 7, 11] {
                100.0
            } else {
                -(g.iter().map(|&x| x as f64).sum::<f64>())
            }
        }
    }

    #[test]
    fn explores_beyond_greedy_gradient() {
        // The gradient pulls to all-zero; the spike is elsewhere. With
        // enough generations across seeds the GA should land on [0,0,0]
        // at worst and the spike in several seeds — check it never
        // returns something *worse* than the greedy answer.
        for seed in 0..5 {
            let out = run(&Spike, &GaParams { seed, generations: 80, ..Default::default() });
            assert!(out.best_fitness >= 0.0, "seed {seed}: {}", out.best_fitness);
        }
    }

    #[test]
    fn evaluation_budget_accounting() {
        let p = MaxSum { lens: vec![4; 3] };
        let params = GaParams { population: 10, generations: 5, ..Default::default() };
        let out = run(&p, &params);
        // init (10) + 5 generations × (10 − 2 carried elites) = 50:
        // elites keep their scores, so they cost no evaluations.
        assert_eq!(out.evaluations, 10 + 5 * (10 - 2));
    }

    /// Counts every fitness() call, to prove elites are not re-scored.
    struct CountingMaxSum {
        lens: Vec<usize>,
        calls: std::cell::Cell<usize>,
    }

    impl GaProblem for CountingMaxSum {
        fn genes(&self) -> usize {
            self.lens.len()
        }
        fn gene_len(&self, g: usize) -> usize {
            self.lens[g]
        }
        fn fitness(&self, genome: &[usize]) -> f64 {
            self.calls.set(self.calls.get() + 1);
            genome.iter().map(|&x| x as f64).sum()
        }
    }

    #[test]
    fn elites_are_never_rescored() {
        let p = CountingMaxSum { lens: vec![6; 4], calls: std::cell::Cell::new(0) };
        let params = GaParams { population: 12, generations: 8, ..Default::default() };
        let out = run(&p, &params);
        assert_eq!(p.calls.get(), out.evaluations);
        assert_eq!(p.calls.get(), 12 + 8 * (12 - 2));
    }

    #[test]
    fn elite_carry_preserves_search_trajectory() {
        // Carrying elite scores must not change what the GA finds:
        // fitness is deterministic and the RNG stream is untouched.
        let p = MaxSum { lens: vec![9; 5] };
        let out = run(&p, &GaParams::default());
        // Near-optimal on a separable problem, and monotone under
        // elitism — the same bar the seed's trajectory cleared.
        assert!(out.best_fitness >= 0.9 * (8.0 * 5.0), "{}", out.best_fitness);
        for w in out.history.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }
}
