//! Search space for the 2-stage Hardware Accelerator Search: the
//! paper's configuration vector F_c = [num, T_a, N_a, T_in, T_out, N_L]
//! (Algorithm 1, line 1), with per-gene bounds and encode/decode
//! between the GA's integer genome and [`HwChoice`].

use crate::resources::{AttnParams, LinearParams};
use crate::sim::HwChoice;
use crate::util::rng::Rng;

/// Candidate values per gene. Powers of two (plus a few mid points)
/// mirror what HLS array-partition pragmas accept without padding
/// waste.
#[derive(Clone, Debug)]
pub struct Space {
    pub num: Vec<usize>,
    pub t_a: Vec<usize>,
    pub n_a: Vec<usize>,
    pub t_in: Vec<usize>,
    pub t_out: Vec<usize>,
    pub n_l: Vec<usize>,
    pub q_bits: u32,
    pub a_bits: u32,
}

impl Space {
    /// Default space used for the paper's platforms.
    pub fn paper(q_bits: u32, a_bits: u32) -> Space {
        Space {
            num: vec![1, 2, 3, 4],
            t_a: vec![2, 4, 8, 12, 16, 24, 32],
            n_a: vec![1, 2, 4, 6, 8, 12, 16, 24, 32],
            t_in: vec![2, 4, 8, 16, 24, 32],
            t_out: vec![2, 4, 8, 16, 24, 32],
            n_l: vec![1, 2, 3, 4, 6, 8, 12, 16],
            q_bits,
            a_bits,
        }
    }

    pub const GENES: usize = 5; // [T_a, N_a, T_in, T_out, N_L]; num is staged

    /// Genome = indices into the candidate lists (num handled by the
    /// outer stage loop in Algorithm 1, line 4).
    pub fn decode(&self, num: usize, genome: &[usize; 5]) -> HwChoice {
        HwChoice {
            num,
            attn: AttnParams { t_a: self.t_a[genome[0]], n_a: self.n_a[genome[1]] },
            lin: LinearParams {
                t_in: self.t_in[genome[2]],
                t_out: self.t_out[genome[3]],
                n_l: self.n_l[genome[4]],
            },
            q_bits: self.q_bits,
            a_bits: self.a_bits,
        }
    }

    pub fn gene_len(&self, gene: usize) -> usize {
        match gene {
            0 => self.t_a.len(),
            1 => self.n_a.len(),
            2 => self.t_in.len(),
            3 => self.t_out.len(),
            4 => self.n_l.len(),
            _ => unreachable!("gene index {gene}"),
        }
    }

    pub fn random_genome(&self, rng: &mut Rng) -> [usize; 5] {
        let mut g = [0usize; 5];
        for (i, slot) in g.iter_mut().enumerate() {
            *slot = rng.below(self.gene_len(i));
        }
        g
    }

    /// Total configurations per `num` (for reporting search coverage).
    pub fn cardinality(&self) -> usize {
        (0..Self::GENES).map(|i| self.gene_len(i)).product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_picks_listed_values() {
        let s = Space::paper(16, 32);
        let hw = s.decode(2, &[0, 1, 2, 3, 4]);
        assert_eq!(hw.num, 2);
        assert_eq!(hw.attn.t_a, s.t_a[0]);
        assert_eq!(hw.attn.n_a, s.n_a[1]);
        assert_eq!(hw.lin.t_in, s.t_in[2]);
        assert_eq!(hw.lin.t_out, s.t_out[3]);
        assert_eq!(hw.lin.n_l, s.n_l[4]);
    }

    #[test]
    fn random_genomes_in_bounds() {
        let s = Space::paper(16, 32);
        let mut rng = Rng::new(1);
        for _ in 0..500 {
            let g = s.random_genome(&mut rng);
            for (i, &v) in g.iter().enumerate() {
                assert!(v < s.gene_len(i));
            }
        }
    }

    #[test]
    fn cardinality_is_product() {
        let s = Space::paper(16, 32);
        assert_eq!(s.cardinality(), 7 * 9 * 6 * 6 * 8);
    }
}
