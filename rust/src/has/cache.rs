//! Persistent design-artifact cache: content-addressed, versioned,
//! on-disk memoization of the design→latency pipeline.
//!
//! Every study entry point (`report::serving_study`, `fleet_curve`
//! fixtures, `report::deploy_many`, `DeviceModel::from_search`) needs
//! the same expensive chain per (platform, model, bit-width, budget,
//! GA budget, seed) grid point: the two-stage HAS search (GA + binary
//! search) followed by cycle-simulator walks for the operating point
//! and the batch-latency surface. The chain is **deterministic** — the
//! GA is seeded, the simulator is analytic — so its output is a pure
//! function of the search inputs. This module persists that output as
//! a [`DesignArtifact`] keyed by a content hash of the inputs: a warm
//! process performs **zero GA evaluations and zero cycle-sim walks**
//! for cached grid points (asserted via [`crate::util::counters`] in
//! `rust/tests/design_cache.rs` and shown by the cold/warm rows of
//! `benches/has_search.rs`).
//!
//! ## Keying (content addressing)
//!
//! [`design_key`] canonicalizes *every* input the pipeline reads:
//! model shape, platform envelope (device resources, derate → budget,
//! frequency, memory fabric, power coefficients), bit-widths, the full
//! HAS search space, and the GA hyperparameters including the seed.
//! Floats are rendered as exact bit patterns, so two keys are equal
//! iff the pipeline would compute bit-identical results. The artifact
//! file stores the full key and is addressed by its FNV-1a hash; on
//! load the stored key is compared byte-for-byte, so a hash collision
//! degrades to a cache miss, never a wrong artifact.
//!
//! ## Versioning and fallback
//!
//! Artifacts carry [`SCHEMA_VERSION`]. A version bump, a key mismatch,
//! or any parse failure makes [`DesignCache::load`] return `None` —
//! callers fall back to a cold search and overwrite the stale file.
//! Corrupt cache state can cost time, never correctness.
//!
//! ## Scope
//!
//! The cache is **opt-in per process**: the library default is
//! disabled (tests stay hermetic); the CLI enables `.ubimoe-cache/`
//! unless `--design-cache none` is passed; benches point it at
//! scratch directories to measure cold vs warm honestly.

use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Duration;

use crate::coordinator::metrics::LatencyStats;
use crate::has::{HasConfig, HasResult, HasStage};
use crate::models::ModelConfig;
use crate::resources::{Platform, Resources};
use crate::serve::autoscale::AutoscaleSummary;
use crate::serve::metrics::DeviceMetrics;
use crate::serve::{FaultSummary, FleetReport, OverloadSummary, ServeConfig, ShardSummary};
use crate::sim::engine::{simulate_with_surface, LatencySurface, SimConfig, SimResult};
use crate::sim::moe::expert_stream_cycles;
use crate::sim::timeline::Timeline;
use crate::sim::HwChoice;
use crate::util::counters;

/// Artifact schema version. Bump whenever the stored fields or their
/// semantics change; old files then read as misses.
pub const SCHEMA_VERSION: u32 = 1;

/// Fleet-report artifact schema version (`fleet-*.txt` files; see
/// [`fleet_to_text`]). Versioned independently of the design schema —
/// a DES metrics change invalidates fleet reports, not designs.
pub const FLEET_SCHEMA_VERSION: u32 = 1;

/// Batch sizes the persisted latency surface covers (`service(B)` for
/// B in 1..=MAX). The surface is affine (`fill + B·period`) and
/// consumers (`DeviceModel::from_surface`) rebuild their LUT from
/// `single`/`period` alone, for any batch size — the persisted table
/// is a human-readable record of the surface, not load-bearing state,
/// so resizing this constant changes only the artifact file.
pub const SURFACE_BATCHES: usize = 16;

/// Everything the design→latency pipeline produces for one key: the
/// chosen hardware, the search diagnostics, the simulated operating
/// point, the batch-latency surface, and the per-expert weight-stream
/// cycles (the residency-discount source).
#[derive(Clone, Debug)]
pub struct DesignArtifact {
    pub has: HasResult,
    /// Simulated operating point of `has.hw`. The timeline is not
    /// persisted: artifacts loaded from disk carry an empty one
    /// (report tables read only the scalar fields; Fig. 3 runs its
    /// own simulation).
    pub sim: SimResult,
    /// `service(B)` surface of `has.hw` (cycles; see
    /// [`crate::sim::engine::latency_surface`]).
    pub surface: LatencySurface,
    /// Exposed leading expert weight-stream (cycles); 0 for models
    /// without experts. See [`expert_stream_cycles`].
    pub expert_stream_cycles: f64,
}

/// Canonical cache key: every input the deterministic pipeline reads,
/// floats as exact bit patterns. One line, `;`-joined sections.
pub fn design_key(model: &ModelConfig, platform: &Platform, cfg: &HasConfig) -> String {
    let m = model;
    let p = platform;
    let s = &cfg.space;
    let g = &cfg.ga;
    let list = |xs: &[usize]| {
        xs.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
    };
    format!(
        "model={} {} {} {} {} {} {} {} {} {} {} {} {} {};\
         platform={} dev={},{},{},{} derate={} freq={} bw={} chan={} slr={},{} \
         pw={},{},{},{};\
         space=q{} a{} num={} t_a={} n_a={} t_in={} t_out={} n_l={};\
         ga=pop{} gen{} tour{} cx={} mut={} elite{} seed={:#x}",
        m.name,
        m.dim,
        m.heads,
        m.depth,
        m.patches,
        m.mlp_ratio,
        m.num_experts,
        m.top_k,
        m.expert_hidden,
        m.moe_every,
        m.img_size,
        m.patch_size,
        m.in_chans,
        m.num_classes,
        p.name,
        f64_hex(p.device.dsp),
        f64_hex(p.device.bram18),
        f64_hex(p.device.lut),
        f64_hex(p.device.ff),
        f64_hex(p.derate),
        f64_hex(p.freq_mhz),
        f64_hex(p.bw_gbs),
        p.mem_channels,
        p.slrs,
        p.mem_slr,
        f64_hex(p.static_w),
        f64_hex(p.dsp_mw_per_mhz),
        f64_hex(p.bram_mw_per_mhz),
        f64_hex(p.chan_w),
        s.q_bits,
        s.a_bits,
        list(&s.num),
        list(&s.t_a),
        list(&s.n_a),
        list(&s.t_in),
        list(&s.t_out),
        list(&s.n_l),
        g.population,
        g.generations,
        g.tournament,
        f64_hex(g.crossover_p),
        f64_hex(g.mutation_p),
        g.elites,
        g.seed,
    )
}

/// Run the full cold pipeline for one key: HAS search, operating-point
/// simulation, latency surface, expert weight-stream.
pub fn compute_design(
    model: &ModelConfig,
    platform: &Platform,
    cfg: &HasConfig,
) -> DesignArtifact {
    let has = crate::has::search(model, platform, cfg);
    artifact_for(model, platform, &has)
}

/// Wrap an already-computed [`HasResult`] into a full artifact (the
/// cycle-model half of the cold pipeline). Shared by [`compute_design`]
/// and `HasEngine::search_cached`.
pub fn artifact_for(
    model: &ModelConfig,
    platform: &Platform,
    has: &HasResult,
) -> DesignArtifact {
    let sc = SimConfig::new(model.clone(), platform.clone(), has.hw);
    // One kernel-model evaluation yields both the operating point and
    // the surface (bit-identical to separate simulate/latency_surface
    // calls — engine test `simulate_with_surface_matches_separate_calls`).
    let (sim, surface) = simulate_with_surface(&sc, SURFACE_BATCHES);
    let stream = if model.num_experts > 0 {
        expert_stream_cycles(model, &sc.memory(), sc.bw.moe_weights)
    } else {
        0.0
    };
    DesignArtifact { has: has.clone(), sim, surface, expert_stream_cycles: stream }
}

// ---------------------------------------------------------------------
// Process-global cache configuration.

static GLOBAL_DIR: Mutex<Option<PathBuf>> = Mutex::new(None);

/// Configure the process-wide design cache directory. `None` disables
/// caching (the library default — unit tests stay hermetic). The CLI
/// sets this from `--design-cache DIR` (default `.ubimoe-cache/`).
pub fn set_global_dir(dir: Option<PathBuf>) {
    *GLOBAL_DIR.lock().expect("design-cache config poisoned") = dir;
}

/// The currently configured global cache directory, if any.
pub fn global_dir() -> Option<PathBuf> {
    GLOBAL_DIR.lock().expect("design-cache config poisoned").clone()
}

/// Handle to one artifact directory (or a disabled no-op cache).
#[derive(Clone, Debug)]
pub struct DesignCache {
    dir: Option<PathBuf>,
}

impl DesignCache {
    /// Cache rooted at `dir` (created lazily on first store).
    pub fn at(dir: impl Into<PathBuf>) -> DesignCache {
        DesignCache { dir: Some(dir.into()) }
    }

    /// No-op cache: every load misses (uncounted), every store is
    /// dropped.
    pub fn disabled() -> DesignCache {
        DesignCache { dir: None }
    }

    /// Snapshot of the process-global configuration.
    pub fn global() -> DesignCache {
        DesignCache { dir: global_dir() }
    }

    pub fn is_enabled(&self) -> bool {
        self.dir.is_some()
    }

    fn path_for(&self, key: &str) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("design-{:016x}.txt", fnv1a(key))))
    }

    /// Load the artifact for `key`. Any schema/version/key mismatch or
    /// parse failure is a miss — cold fallback, never a panic.
    pub fn load(&self, key: &str) -> Option<DesignArtifact> {
        let path = self.path_for(key)?;
        let parsed =
            std::fs::read_to_string(&path).ok().and_then(|t| DesignArtifact::from_text(&t, key));
        match parsed {
            Some(a) => {
                counters::count_cache_hit();
                Some(a)
            }
            None => {
                counters::count_cache_miss();
                None
            }
        }
    }

    /// Persist the artifact for `key` (best-effort: IO errors leave
    /// the cache cold but never fail the computation). Writes to a
    /// temp file and renames, so concurrent writers of the same key —
    /// e.g. `deploy_many` workers — each land a complete file.
    pub fn store(&self, key: &str, artifact: &DesignArtifact) {
        let Some(path) = self.path_for(key) else { return };
        if write_atomic(&path, &artifact.to_text(key)) {
            counters::count_cache_store();
        }
    }

    /// The memoized pipeline: load on hit, otherwise run the cold
    /// pipeline and persist the result.
    pub fn get_or_compute(
        &self,
        model: &ModelConfig,
        platform: &Platform,
        cfg: &HasConfig,
    ) -> DesignArtifact {
        let key = design_key(model, platform, cfg);
        if let Some(a) = self.load(&key) {
            return a;
        }
        let a = compute_design(model, platform, cfg);
        self.store(&key, &a);
        a
    }
}

/// [`DesignCache::get_or_compute`] against the process-global cache —
/// the single entry point `report::deploy` and
/// `serve::device::DeviceModel::from_search` go through.
pub fn cached_design(
    model: &ModelConfig,
    platform: &Platform,
    cfg: &HasConfig,
) -> DesignArtifact {
    DesignCache::global().get_or_compute(model, platform, cfg)
}

/// Create-dirs + unique-temp-file + rename write. Best-effort: any IO
/// failure returns `false` and leaves the cache cold. Unique temp name
/// per (process, call): concurrent writers of the same key — e.g. two
/// sweep workers — never share a temp file, and the rename makes the
/// final artifact appear whole.
fn write_atomic(path: &std::path::Path, text: &str) -> bool {
    let Some(dir) = path.parent() else { return false };
    if std::fs::create_dir_all(dir).is_err() {
        return false;
    }
    static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = TMP_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let tmp = path.with_extension(format!("tmp.{}.{seq}", std::process::id()));
    std::fs::write(&tmp, text).is_ok() && std::fs::rename(&tmp, path).is_ok()
}

// ---------------------------------------------------------------------
// Whole-DES memoization: FleetReport artifacts keyed by
// `ServeConfig::canonical_key()` (ISSUE 10). Same discipline as the
// design artifacts — content-addressed `fleet-*.txt` files, stored-key
// byte compare, independent schema version, floats as bit patterns,
// any corruption ⇒ miss ⇒ cold event loop. The DES is deterministic
// (fixed (config, seed) ⇒ bit-identical report), so a disk hit stands
// in for the event loop exactly; warm plan reruns perform zero DES
// work (counter-asserted in `rust/tests/fleet_cache.rs`).

impl DesignCache {
    fn fleet_path_for(&self, key: &str) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join(format!("fleet-{:016x}.txt", fnv1a(key))))
    }

    /// Load the memoized [`FleetReport`] for a canonical serve key.
    /// Any schema/version/key mismatch or parse failure is a miss.
    pub fn load_fleet(&self, key: &str) -> Option<FleetReport> {
        let path = self.fleet_path_for(key)?;
        let parsed =
            std::fs::read_to_string(&path).ok().and_then(|t| fleet_from_text(&t, key));
        match parsed {
            Some(r) => {
                counters::count_cache_hit();
                Some(r)
            }
            None => {
                counters::count_cache_miss();
                None
            }
        }
    }

    /// Persist a [`FleetReport`] under its canonical key (best-effort,
    /// atomic — same contract as [`DesignCache::store`]).
    pub fn store_fleet(&self, key: &str, report: &FleetReport) {
        let Some(path) = self.fleet_path_for(key) else { return };
        if write_atomic(&path, &fleet_to_text(key, report)) {
            counters::count_cache_store();
        }
    }

    /// The memoized DES: load on hit, otherwise run the event loop and
    /// persist the result. The single entry point the fleet planner's
    /// fitness function goes through ([`crate::has::fleet`]).
    pub fn get_or_compute_fleet(&self, cfg: &ServeConfig) -> FleetReport {
        let key = cfg.canonical_key();
        if let Some(r) = self.load_fleet(&key) {
            return r;
        }
        let r = crate::serve::simulate_fleet(cfg);
        self.store_fleet(&key, &r);
        r
    }
}

/// [`DesignCache::get_or_compute_fleet`] against the process-global
/// cache — the DES analog of [`cached_design`].
pub fn cached_fleet(cfg: &ServeConfig) -> FleetReport {
    DesignCache::global().get_or_compute_fleet(cfg)
}

/// Serialize a [`FleetReport`] to the strict line-oriented fleet
/// artifact format. Histograms ride the [`LatencyStats`] wire codec
/// (sparse nonzero buckets — exact), floats are 16-hex bit patterns,
/// durations integer nanoseconds. The fleet-wide rollup is *not*
/// stored: [`fleet_from_text`] rebuilds it by the same `merge_from`
/// fold `simulate_fleet` uses, so it is bit-identical by construction.
pub fn fleet_to_text(key: &str, r: &FleetReport) -> String {
    use std::fmt::Write as _;
    let mut t = format!("ubimoe-fleet v{FLEET_SCHEMA_VERSION}\nkey={key}\n");
    let _ = writeln!(
        t,
        "scalars={},{},{},{},{},{},{},{},{}",
        r.admitted,
        f64_hex(r.offered_rps),
        r.horizon.as_nanos(),
        r.makespan.as_nanos(),
        r.events,
        r.peak_events,
        f64_hex(r.device_seconds),
        r.dropped,
        r.rejected
    );
    let _ = writeln!(t, "devs={}", r.per_device.len());
    for d in &r.per_device {
        let _ = writeln!(
            t,
            "dev={};{};{};{},{},{},{},{}",
            d.queue_wait.to_wire(),
            d.service.to_wire(),
            d.e2e.to_wire(),
            d.completed,
            d.batches,
            d.slots,
            d.padded_slots,
            d.busy.as_nanos()
        );
    }
    match &r.autoscale {
        None => t.push_str("as=none\n"),
        Some(a) => {
            let _ = writeln!(
                t,
                "as={},{},{},{},{},{}",
                a.ticks, a.scale_ups, a.scale_downs, a.peak_active, a.min_active,
                a.final_active
            );
        }
    }
    match &r.faults {
        None => t.push_str("ft=none\n"),
        Some(fs) => {
            let down = fs
                .downtime
                .iter()
                .map(|d| d.as_nanos().to_string())
                .collect::<Vec<_>>()
                .join(",");
            let _ = writeln!(
                t,
                "ft={},{},{},{},{},{},{},{},{};{down}",
                fs.device_failures,
                fs.lost_batches,
                fs.wasted_service.as_nanos(),
                fs.failovers,
                fs.retries,
                fs.dropped,
                fs.seu_reruns,
                fs.hedges,
                fs.hedge_wins
            );
        }
    }
    match &r.overload {
        None => t.push_str("ov=none\n"),
        Some(o) => {
            let mut nums: Vec<u64> = Vec::with_capacity(20);
            nums.extend_from_slice(&o.offered_by_class);
            nums.extend_from_slice(&o.admitted_by_class);
            nums.extend_from_slice(&o.completed_by_class);
            nums.extend_from_slice(&o.rejected_by_class);
            nums.extend_from_slice(&[
                o.rejected,
                o.rejected_rate,
                o.rejected_queue,
                o.breaker_trips,
                o.breaker_closes,
                o.brownout_enters,
                o.brownout_windows,
                o.degraded_completions,
            ]);
            let nums_s =
                nums.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",");
            let _ = writeln!(
                t,
                "ov={nums_s};{};{};{};{}",
                o.e2e_by_class[0].to_wire(),
                o.e2e_by_class[1].to_wire(),
                o.e2e_by_class[2].to_wire(),
                f64_hex(o.accuracy_cost)
            );
        }
    }
    match &r.shard {
        None => t.push_str("sh=none\n"),
        Some(s) => {
            let _ = writeln!(
                t,
                "sh={},{},{},{},{},{},{},{},{},{};{}",
                s.routed,
                s.rerouted,
                s.expert_drops,
                s.no_replica_drops,
                s.transfers,
                s.transfer_ns,
                s.replica_adds,
                s.replica_drops,
                s.rebalances,
                s.degraded_completions,
                f64_hex(s.accuracy_cost)
            );
        }
    }
    t
}

/// Strict inverse of [`fleet_to_text`]: `None` on any structural,
/// version, or key mismatch (the cold-fallback contract — corruption
/// costs an event loop, never correctness).
pub fn fleet_from_text(text: &str, expect_key: &str) -> Option<FleetReport> {
    let mut lines = text.lines();
    if lines.next()? != format!("ubimoe-fleet v{FLEET_SCHEMA_VERSION}") {
        return None;
    }
    let mut field = |name: &str| -> Option<String> {
        let line = lines.next()?;
        line.strip_prefix(name)?.strip_prefix('=').map(str::to_string)
    };
    if field("key")? != expect_key {
        return None;
    }
    let scal = field("scalars")?;
    let sv: Vec<&str> = scal.split(',').collect();
    if sv.len() != 9 {
        return None;
    }
    let admitted: u64 = sv[0].parse().ok()?;
    let offered_rps = parse_f64_hex(sv[1])?;
    let horizon = Duration::from_nanos(sv[2].parse().ok()?);
    let makespan = Duration::from_nanos(sv[3].parse().ok()?);
    let events: u64 = sv[4].parse().ok()?;
    let peak_events: u64 = sv[5].parse().ok()?;
    let device_seconds = parse_f64_hex(sv[6])?;
    let dropped: u64 = sv[7].parse().ok()?;
    let rejected: u64 = sv[8].parse().ok()?;

    let ndev: usize = field("devs")?.parse().ok()?;
    let mut per_device: Vec<DeviceMetrics> = Vec::with_capacity(ndev);
    for _ in 0..ndev {
        let line = field("dev")?;
        let mut secs = line.split(';');
        let queue_wait = LatencyStats::from_wire(secs.next()?)?;
        let service = LatencyStats::from_wire(secs.next()?)?;
        let e2e = LatencyStats::from_wire(secs.next()?)?;
        let tail = secs.next()?;
        if secs.next().is_some() {
            return None;
        }
        let tv: Vec<&str> = tail.split(',').collect();
        if tv.len() != 5 {
            return None;
        }
        per_device.push(DeviceMetrics {
            queue_wait,
            service,
            e2e,
            completed: tv[0].parse().ok()?,
            batches: tv[1].parse().ok()?,
            slots: tv[2].parse().ok()?,
            padded_slots: tv[3].parse().ok()?,
            busy: Duration::from_nanos(tv[4].parse().ok()?),
        });
    }

    let a_line = field("as")?;
    let autoscale = if a_line == "none" {
        None
    } else {
        let av: Vec<&str> = a_line.split(',').collect();
        if av.len() != 6 {
            return None;
        }
        Some(AutoscaleSummary {
            ticks: av[0].parse().ok()?,
            scale_ups: av[1].parse().ok()?,
            scale_downs: av[2].parse().ok()?,
            peak_active: av[3].parse().ok()?,
            min_active: av[4].parse().ok()?,
            final_active: av[5].parse().ok()?,
        })
    };

    let f_line = field("ft")?;
    let faults = if f_line == "none" {
        None
    } else {
        let (nums, down) = f_line.split_once(';')?;
        let fv: Vec<&str> = nums.split(',').collect();
        if fv.len() != 9 {
            return None;
        }
        let downtime: Vec<Duration> = if down.is_empty() {
            Vec::new()
        } else {
            down.split(',')
                .map(|s| s.parse::<u64>().ok().map(Duration::from_nanos))
                .collect::<Option<Vec<_>>>()?
        };
        Some(FaultSummary {
            device_failures: fv[0].parse().ok()?,
            lost_batches: fv[1].parse().ok()?,
            wasted_service: Duration::from_nanos(fv[2].parse().ok()?),
            failovers: fv[3].parse().ok()?,
            retries: fv[4].parse().ok()?,
            dropped: fv[5].parse().ok()?,
            seu_reruns: fv[6].parse().ok()?,
            hedges: fv[7].parse().ok()?,
            hedge_wins: fv[8].parse().ok()?,
            downtime,
        })
    };

    let o_line = field("ov")?;
    let overload = if o_line == "none" {
        None
    } else {
        let mut secs = o_line.split(';');
        let nums: Vec<u64> = secs
            .next()?
            .split(',')
            .map(|s| s.parse().ok())
            .collect::<Option<Vec<_>>>()?;
        if nums.len() != 20 {
            return None;
        }
        let e0 = LatencyStats::from_wire(secs.next()?)?;
        let e1 = LatencyStats::from_wire(secs.next()?)?;
        let e2 = LatencyStats::from_wire(secs.next()?)?;
        let accuracy_cost = parse_f64_hex(secs.next()?)?;
        if secs.next().is_some() {
            return None;
        }
        Some(OverloadSummary {
            offered_by_class: [nums[0], nums[1], nums[2]],
            admitted_by_class: [nums[3], nums[4], nums[5]],
            completed_by_class: [nums[6], nums[7], nums[8]],
            rejected_by_class: [nums[9], nums[10], nums[11]],
            e2e_by_class: [e0, e1, e2],
            rejected: nums[12],
            rejected_rate: nums[13],
            rejected_queue: nums[14],
            breaker_trips: nums[15],
            breaker_closes: nums[16],
            brownout_enters: nums[17],
            brownout_windows: nums[18],
            degraded_completions: nums[19],
            accuracy_cost,
        })
    };

    let s_line = field("sh")?;
    let shard = if s_line == "none" {
        None
    } else {
        let (nums, acc) = s_line.split_once(';')?;
        let nv: Vec<u64> =
            nums.split(',').map(|s| s.parse().ok()).collect::<Option<Vec<_>>>()?;
        if nv.len() != 10 {
            return None;
        }
        Some(ShardSummary {
            routed: nv[0],
            rerouted: nv[1],
            expert_drops: nv[2],
            no_replica_drops: nv[3],
            transfers: nv[4],
            transfer_ns: nv[5],
            replica_adds: nv[6],
            replica_drops: nv[7],
            rebalances: nv[8],
            degraded_completions: nv[9],
            accuracy_cost: parse_f64_hex(acc)?,
        })
    };

    // Rebuild the fleet-wide rollup by the same fold `simulate_fleet`
    // performs — bit-identical by construction, and one fewer stored
    // copy that could drift from its parts.
    let mut fleet = DeviceMetrics::default();
    for d in &per_device {
        fleet.merge_from(d);
    }
    Some(FleetReport {
        per_device,
        fleet,
        admitted,
        offered_rps,
        horizon,
        makespan,
        events,
        peak_events,
        device_seconds,
        autoscale,
        dropped,
        faults,
        rejected,
        overload,
        shard,
    })
}

// ---------------------------------------------------------------------
// Maintenance: `ubimoe cache stats` / `ubimoe cache gc`.

/// On-disk footprint of a cache directory ([`DesignCache::stats`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Complete `design-*.txt` / `fleet-*.txt` artifact files.
    pub artifacts: u64,
    /// Bytes across those artifacts.
    pub total_bytes: u64,
    /// Leftover `*.tmp.*` files from interrupted writers.
    pub stale_tmp: u64,
}

/// What [`DesignCache::gc`] did.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct GcReport {
    /// Artifact files found before eviction.
    pub scanned: u64,
    /// Artifact files evicted (oldest modification time first).
    pub evicted: u64,
    pub bytes_freed: u64,
    /// Bytes remaining in surviving artifacts.
    pub bytes_kept: u64,
    /// Stale temp files removed (always, regardless of the budget).
    pub stale_tmp_removed: u64,
}

/// (path, byte length, mtime) of every artifact in the directory.
/// Sorted oldest-first, file name breaking mtime ties so the eviction
/// order is deterministic on coarse-timestamp filesystems.
fn artifact_entries(dir: &std::path::Path) -> Vec<(PathBuf, u64, std::time::SystemTime)> {
    let Ok(rd) = std::fs::read_dir(dir) else { return Vec::new() };
    let mut entries: Vec<(PathBuf, u64, std::time::SystemTime)> = rd
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            let path = e.path();
            let name = path.file_name()?.to_str()?;
            let is_artifact = (name.starts_with("design-") || name.starts_with("fleet-"))
                && name.ends_with(".txt");
            if !is_artifact {
                return None;
            }
            let meta = e.metadata().ok()?;
            let mtime = meta.modified().ok()?;
            Some((path, meta.len(), mtime))
        })
        .collect();
    entries.sort_by(|a, b| a.2.cmp(&b.2).then_with(|| a.0.cmp(&b.0)));
    entries
}

impl DesignCache {
    /// Count artifacts and bytes in the cache directory (a disabled
    /// cache reports zeros).
    pub fn stats(&self) -> CacheStats {
        let Some(dir) = &self.dir else { return CacheStats::default() };
        let entries = artifact_entries(dir);
        let stale_tmp = std::fs::read_dir(dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter(|e| {
                        e.path()
                            .file_name()
                            .and_then(|n| n.to_str())
                            .is_some_and(|n| n.contains(".tmp."))
                    })
                    .count() as u64
            })
            .unwrap_or(0);
        CacheStats {
            artifacts: entries.len() as u64,
            total_bytes: entries.iter().map(|e| e.1).sum(),
            stale_tmp,
        }
    }

    /// Size-bounded LRU eviction: delete artifacts oldest-mtime-first
    /// until the directory total is ≤ `max_bytes` (recency ≈ write
    /// time — the cache never rewrites an artifact on a hit, so mtime
    /// is creation time and eviction is oldest-design-first). Stale
    /// `*.tmp.*` files from interrupted writers are always removed; a
    /// writer racing the sweep merely loses its best-effort store
    /// (cold recompute next run — the cache's usual degradation,
    /// never corruption, because readers only see whole renamed
    /// files). Disabled caches and IO errors report zeros — gc is
    /// best-effort like every other cache path.
    pub fn gc(&self, max_bytes: u64) -> GcReport {
        let Some(dir) = &self.dir else { return GcReport::default() };
        let mut report = GcReport::default();
        if let Ok(rd) = std::fs::read_dir(dir) {
            for e in rd.filter_map(|e| e.ok()) {
                let path = e.path();
                let is_tmp = path
                    .file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.contains(".tmp."));
                if is_tmp && std::fs::remove_file(&path).is_ok() {
                    report.stale_tmp_removed += 1;
                }
            }
        }
        let entries = artifact_entries(dir);
        report.scanned = entries.len() as u64;
        let mut total: u64 = entries.iter().map(|e| e.1).sum();
        for (path, len, _) in &entries {
            if total <= max_bytes {
                break;
            }
            if std::fs::remove_file(path).is_ok() {
                report.evicted += 1;
                report.bytes_freed += len;
                total -= len;
            }
        }
        report.bytes_kept = total;
        report
    }
}

// ---------------------------------------------------------------------
// Serialization: a strict line-oriented text format. Floats are stored
// as 16-hex-digit IEEE-754 bit patterns so a disk round trip is exact
// — the cold-vs-warm bit-identity proptests depend on it.

impl DesignArtifact {
    pub fn to_text(&self, key: &str) -> String {
        let h = &self.has;
        let s = &self.sim;
        let hw = h.hw;
        let stage = match h.stage {
            HasStage::BalancedAtMoE => "balanced-at-moe",
            HasStage::MsaBoundMinimized => "msa-bound-minimized",
        };
        format!(
            "ubimoe-design v{SCHEMA_VERSION}\n\
             key={key}\n\
             hw={},{},{},{},{},{},{},{}\n\
             stage={stage}\n\
             has={},{},{},{}\n\
             res={},{},{},{}\n\
             ga={},{},{}\n\
             history={}\n\
             sim={},{},{},{},{},{},{},{},{},{}\n\
             surface={},{}\n\
             service={}\n\
             stream={}\n",
            hw.num,
            hw.attn.t_a,
            hw.attn.n_a,
            hw.lin.t_in,
            hw.lin.t_out,
            hw.lin.n_l,
            hw.q_bits,
            hw.a_bits,
            f64_hex(h.l_msa),
            f64_hex(h.l_moe),
            f64_hex(h.l_bound),
            f64_hex(h.fit_score),
            f64_hex(h.resources.dsp),
            f64_hex(h.resources.bram18),
            f64_hex(h.resources.lut),
            f64_hex(h.resources.ff),
            h.ga_evaluations,
            h.ga_true_evaluations,
            h.ga_cache_hits,
            hex_list(&h.ga_history),
            f64_hex(s.msa_cycles),
            f64_hex(s.ffn_cycles),
            f64_hex(s.moe_cycles),
            f64_hex(s.total_cycles),
            f64_hex(s.latency_ms),
            f64_hex(s.gop),
            f64_hex(s.gops),
            f64_hex(s.power_w),
            f64_hex(s.gops_per_w),
            f64_hex(s.overlap_fraction),
            f64_hex(self.surface.single_cycles),
            f64_hex(self.surface.period_cycles),
            hex_list(&self.surface.service_cycles),
            f64_hex(self.expert_stream_cycles),
        )
    }

    /// Strict parse: `None` on any structural, version, or key
    /// mismatch (the cold-fallback contract).
    pub fn from_text(text: &str, expect_key: &str) -> Option<DesignArtifact> {
        let mut lines = text.lines();
        if lines.next()? != format!("ubimoe-design v{SCHEMA_VERSION}") {
            return None;
        }
        let mut field = |name: &str| -> Option<String> {
            let line = lines.next()?;
            line.strip_prefix(name)?.strip_prefix('=').map(str::to_string)
        };

        if field("key")? != expect_key {
            return None;
        }
        let hw_v = parse_usize_list(&field("hw")?, 8)?;
        let hw = HwChoice {
            num: hw_v[0],
            attn: crate::resources::AttnParams { t_a: hw_v[1], n_a: hw_v[2] },
            lin: crate::resources::LinearParams {
                t_in: hw_v[3],
                t_out: hw_v[4],
                n_l: hw_v[5],
            },
            q_bits: hw_v[6] as u32,
            a_bits: hw_v[7] as u32,
        };
        let stage = match field("stage")?.as_str() {
            "balanced-at-moe" => HasStage::BalancedAtMoE,
            "msa-bound-minimized" => HasStage::MsaBoundMinimized,
            _ => return None,
        };
        let has_v = parse_f64_list(&field("has")?, Some(4))?;
        let res_v = parse_f64_list(&field("res")?, Some(4))?;
        let resources =
            Resources { dsp: res_v[0], bram18: res_v[1], lut: res_v[2], ff: res_v[3] };
        let ga_v = parse_usize_list(&field("ga")?, 3)?;
        let history = parse_f64_list(&field("history")?, None)?;
        let sim_v = parse_f64_list(&field("sim")?, Some(10))?;
        let surf_v = parse_f64_list(&field("surface")?, Some(2))?;
        let service = parse_f64_list(&field("service")?, None)?;
        let stream = parse_f64_list(&field("stream")?, Some(1))?[0];

        let has = HasResult {
            hw,
            stage,
            l_msa: has_v[0],
            l_moe: has_v[1],
            l_bound: has_v[2],
            fit_score: has_v[3],
            resources,
            ga_evaluations: ga_v[0],
            ga_true_evaluations: ga_v[1],
            ga_cache_hits: ga_v[2],
            ga_history: history,
        };
        let sim = SimResult {
            msa_cycles: sim_v[0],
            ffn_cycles: sim_v[1],
            moe_cycles: sim_v[2],
            total_cycles: sim_v[3],
            latency_ms: sim_v[4],
            gop: sim_v[5],
            gops: sim_v[6],
            power_w: sim_v[7],
            gops_per_w: sim_v[8],
            // The design's resources are hw.resources(...) on both the
            // HAS and sim sides — one stored copy serves both.
            resources,
            timeline: Timeline::new("kcycles"),
            overlap_fraction: sim_v[9],
        };
        let surface = LatencySurface {
            single_cycles: surf_v[0],
            period_cycles: surf_v[1],
            service_cycles: service,
        };
        Some(DesignArtifact { has, sim, surface, expert_stream_cycles: stream })
    }
}

fn f64_hex(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn hex_list(xs: &[f64]) -> String {
    xs.iter().map(|&x| f64_hex(x)).collect::<Vec<_>>().join(",")
}

fn parse_f64_hex(s: &str) -> Option<f64> {
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

fn parse_f64_list(s: &str, expect_len: Option<usize>) -> Option<Vec<f64>> {
    let v: Option<Vec<f64>> = if s.is_empty() {
        Some(Vec::new())
    } else {
        s.split(',').map(parse_f64_hex).collect()
    };
    let v = v?;
    match expect_len {
        Some(n) if v.len() != n => None,
        _ => Some(v),
    }
}

fn parse_usize_list(s: &str, expect_len: usize) -> Option<Vec<usize>> {
    let v: Option<Vec<usize>> = s.split(',').map(|x| x.parse().ok()).collect();
    let v = v?;
    if v.len() == expect_len {
        Some(v)
    } else {
        None
    }
}

/// FNV-1a 64-bit — the content-address hash for artifact file names.
/// Collisions are harmless (the stored key is compared on load).
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::{AttnParams, LinearParams};

    fn fake_artifact() -> DesignArtifact {
        let hw = HwChoice {
            num: 2,
            attn: AttnParams { t_a: 8, n_a: 8 },
            lin: LinearParams { t_in: 16, t_out: 16, n_l: 2 },
            q_bits: 16,
            a_bits: 32,
        };
        DesignArtifact {
            has: HasResult {
                hw,
                stage: HasStage::BalancedAtMoE,
                l_msa: 123.456,
                l_moe: 789.0123,
                l_bound: 789.0123,
                fit_score: 1.0625,
                resources: Resources { dsp: 1850.0, bram18: 916.0, lut: 123_400.0, ff: 9.5 },
                ga_evaluations: 1000,
                ga_true_evaluations: 600,
                ga_cache_hits: 400,
                ga_history: vec![0.5, 0.75, 1.0625],
            },
            sim: SimResult {
                msa_cycles: 1.25e5,
                ffn_cycles: 2.5e5,
                moe_cycles: 7.75e5,
                total_cycles: 5.5e6,
                latency_ms: 18.3333333,
                gop: 11.88,
                gops: 648.0,
                power_w: 11.5,
                gops_per_w: 56.3478,
                resources: Resources { dsp: 1850.0, bram18: 916.0, lut: 123_400.0, ff: 9.5 },
                timeline: Timeline::new("kcycles"),
                overlap_fraction: 0.625,
            },
            surface: LatencySurface {
                single_cycles: 7.0e6,
                period_cycles: 5.5e6,
                service_cycles: vec![7.0e6, 12.5e6, 18.0e6],
            },
            expert_stream_cycles: 3.125e4,
        }
    }

    fn artifacts_equal(a: &DesignArtifact, b: &DesignArtifact) -> bool {
        a.has == b.has
            && a.surface == b.surface
            && a.expert_stream_cycles == b.expert_stream_cycles
            && a.sim.total_cycles == b.sim.total_cycles
            && a.sim.latency_ms == b.sim.latency_ms
            && a.sim.gops == b.sim.gops
            && a.sim.power_w == b.sim.power_w
            && a.sim.gops_per_w == b.sim.gops_per_w
            && a.sim.overlap_fraction == b.sim.overlap_fraction
            && a.sim.resources == b.sim.resources
    }

    #[test]
    fn text_roundtrip_is_exact() {
        let a = fake_artifact();
        let text = a.to_text("some-key");
        let b = DesignArtifact::from_text(&text, "some-key").expect("parse");
        assert!(artifacts_equal(&a, &b), "round trip must be bit-exact");
        // Timeline is intentionally not persisted.
        assert!(b.sim.timeline.spans.is_empty());
    }

    #[test]
    fn stale_schema_version_reads_as_miss() {
        let a = fake_artifact();
        let text = a.to_text("k");
        let stale = text.replacen(
            &format!("ubimoe-design v{SCHEMA_VERSION}"),
            "ubimoe-design v0",
            1,
        );
        assert!(DesignArtifact::from_text(&stale, "k").is_none());
    }

    #[test]
    fn key_mismatch_reads_as_miss() {
        let a = fake_artifact();
        let text = a.to_text("key-a");
        assert!(DesignArtifact::from_text(&text, "key-b").is_none());
        assert!(DesignArtifact::from_text(&text, "key-a").is_some());
    }

    #[test]
    fn corrupt_text_reads_as_miss_not_panic() {
        let a = fake_artifact();
        let text = a.to_text("k");
        // Truncations and field-level garbage all degrade to None.
        for cut in [0, 1, text.len() / 2] {
            assert!(DesignArtifact::from_text(&text[..cut], "k").is_none());
        }
        let garbled = text.replace("stage=balanced-at-moe", "stage=wat");
        assert!(DesignArtifact::from_text(&garbled, "k").is_none());
        let short_hw = text.replace("hw=2,8,8,16,16,2,16,32", "hw=2,8,8");
        assert!(DesignArtifact::from_text(&short_hw, "k").is_none());
    }

    #[test]
    fn disk_store_load_roundtrip_and_disabled_noop() {
        let dir = std::env::temp_dir()
            .join(format!("ubimoe-cache-unit-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = DesignCache::at(&dir);
        let a = fake_artifact();
        assert!(cache.load("k1").is_none(), "empty dir must miss");
        cache.store("k1", &a);
        let b = cache.load("k1").expect("hit after store");
        assert!(artifacts_equal(&a, &b));
        // Different key under the same dir: miss.
        assert!(cache.load("k2").is_none());

        let off = DesignCache::disabled();
        off.store("k1", &a);
        assert!(off.load("k1").is_none());
        assert!(!off.is_enabled() && cache.is_enabled());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_evicts_oldest_artifacts_first() {
        let dir = std::env::temp_dir()
            .join(format!("ubimoe-cache-gc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = DesignCache::at(&dir);
        let a = fake_artifact();
        // Distinct mtimes (sleeps are far above CI filesystems'
        // timestamp granularity); insertion order k1 < k2 < k3.
        cache.store("k1", &a);
        std::thread::sleep(std::time::Duration::from_millis(20));
        cache.store("k2", &a);
        std::thread::sleep(std::time::Duration::from_millis(20));
        cache.store("k3", &a);
        let s = cache.stats();
        assert_eq!(s.artifacts, 3);
        assert!(s.total_bytes > 0);
        assert_eq!(s.stale_tmp, 0);

        // Budget of (total − 1) bytes: exactly the single oldest
        // artifact (k1) must go.
        let r = cache.gc(s.total_bytes - 1);
        assert_eq!((r.scanned, r.evicted), (3, 1));
        assert!(cache.load("k1").is_none(), "oldest artifact must be evicted");
        assert!(cache.load("k2").is_some() && cache.load("k3").is_some());
        assert_eq!(r.bytes_kept, cache.stats().total_bytes);
        assert_eq!(r.bytes_freed + r.bytes_kept, s.total_bytes);

        // Re-store k2 (bumps its mtime): k3 becomes the LRU victim.
        std::thread::sleep(std::time::Duration::from_millis(20));
        cache.store("k2", &a);
        let total = cache.stats().total_bytes;
        let r2 = cache.gc(total - 1);
        assert_eq!(r2.evicted, 1);
        assert!(cache.load("k3").is_none(), "k3 was least recently written");
        assert!(cache.load("k2").is_some(), "freshly re-written k2 must survive");

        // Zero budget clears everything; gc of an empty dir is a no-op.
        let r3 = cache.gc(0);
        assert_eq!(r3.evicted, 1);
        assert_eq!(cache.stats(), CacheStats::default());
        assert_eq!(cache.gc(0), GcReport::default());

        // Disabled cache: stats and gc are inert.
        assert_eq!(DesignCache::disabled().stats(), CacheStats::default());
        assert_eq!(DesignCache::disabled().gc(0), GcReport::default());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_sweeps_stale_temp_files() {
        let dir = std::env::temp_dir()
            .join(format!("ubimoe-cache-tmp-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = DesignCache::at(&dir);
        cache.store("k", &fake_artifact());
        // A crashed writer's leftover temp file.
        std::fs::write(dir.join("design-dead.tmp.99.1"), "partial").unwrap();
        assert_eq!(cache.stats().stale_tmp, 1);
        let r = cache.gc(u64::MAX);
        assert_eq!((r.evicted, r.stale_tmp_removed), (0, 1));
        assert!(cache.load("k").is_some(), "budget not exceeded: artifact survives");
        assert_eq!(cache.stats().stale_tmp, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn design_key_separates_inputs() {
        let model = crate::models::m3vit_small();
        let cfg = HasConfig::deployment(16, 32);
        let base = design_key(&model, &Platform::zcu102(), &cfg);
        assert_eq!(base, design_key(&model, &Platform::zcu102(), &cfg), "deterministic");
        assert_ne!(base, design_key(&model, &Platform::u280(), &cfg), "platform in key");
        let mut derated = Platform::zcu102();
        derated.derate = 0.5;
        assert_ne!(base, design_key(&model, &derated, &cfg), "budget in key");
        let mut seeded = cfg.clone();
        seeded.ga.seed ^= 1;
        assert_ne!(base, design_key(&model, &Platform::zcu102(), &seeded), "seed in key");
        let mut bits = HasConfig::deployment(16, 16);
        bits.ga = cfg.ga;
        assert_ne!(base, design_key(&model, &Platform::zcu102(), &bits), "bit-width in key");
        assert_ne!(
            base,
            design_key(&crate::models::vit_t(), &Platform::zcu102(), &cfg),
            "model in key"
        );
        assert!(!base.contains('\n'), "key must be a single line");
    }

    fn small_fleet_report() -> (String, FleetReport) {
        let dev = crate::serve::device::DeviceModel::from_latencies(
            "t".into(),
            Duration::from_millis(2),
            Duration::from_millis(5),
            &[1, 2, 4],
        );
        let cfg = ServeConfig::uniform(
            dev,
            2,
            crate::serve::Workload::Poisson { rate_rps: 120.0 },
        );
        (cfg.canonical_key(), crate::serve::simulate_fleet(&cfg))
    }

    #[test]
    fn fleet_text_roundtrip_is_bit_identical() {
        let (key, r) = small_fleet_report();
        let text = fleet_to_text(&key, &r);
        let back = fleet_from_text(&text, &key).expect("fleet parse");
        assert_eq!(back, r, "round trip must preserve every field bit-exactly");
        // The rollup was rebuilt, not stored — verify it matches too.
        assert_eq!(back.fleet, r.fleet);
    }

    #[test]
    fn fleet_corruption_reads_as_miss() {
        let (key, r) = small_fleet_report();
        let text = fleet_to_text(&key, &r);
        // Version bump, wrong key, truncation, garbage — all miss.
        let stale = text.replacen(
            &format!("ubimoe-fleet v{FLEET_SCHEMA_VERSION}"),
            "ubimoe-fleet v0",
            1,
        );
        assert!(fleet_from_text(&stale, &key).is_none());
        assert!(fleet_from_text(&text, "other-key").is_none());
        for cut in [0, 1, text.len() / 2] {
            assert!(fleet_from_text(&text[..cut], &key).is_none());
        }
        let garbled = text.replacen("scalars=", "scalars=x", 1);
        assert!(fleet_from_text(&garbled, &key).is_none());
    }

    #[test]
    fn fleet_disk_store_load_and_gc_scope() {
        let dir = std::env::temp_dir()
            .join(format!("ubimoe-cache-fleet-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = DesignCache::at(&dir);
        let (key, r) = small_fleet_report();
        assert!(cache.load_fleet(&key).is_none(), "empty dir must miss");
        cache.store_fleet(&key, &r);
        assert_eq!(cache.load_fleet(&key).expect("hit after store"), r);
        // Fleet artifacts are visible to stats/gc alongside designs.
        assert_eq!(cache.stats().artifacts, 1);
        cache.store("dk", &fake_artifact());
        assert_eq!(cache.stats().artifacts, 2);
        assert_eq!(cache.gc(0).evicted, 2);
        assert!(cache.load_fleet(&key).is_none(), "gc evicts fleet artifacts too");
        // Disabled cache: inert on the fleet path as well.
        let off = DesignCache::disabled();
        off.store_fleet(&key, &r);
        assert!(off.load_fleet(&key).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hash_is_stable_fnv1a() {
        // Pinned vectors (standard FNV-1a 64 test values): file names
        // must not silently change across refactors.
        assert_eq!(fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a("a"), 0xaf63_dc4c_8601_ec8c);
    }
}
