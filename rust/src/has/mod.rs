//! 2-stage Hardware Accelerator Search (Algorithm 1).
//!
//! Stage "MoE part 1" (line 3): the best achievable MoE-block latency
//! L_MoE under the platform budget (reserving a minimal MSA) — this is
//! the *target* the MSA stage balances against.
//! Stage "MSA" (lines 4–10): per candidate `num`, a GA searches the
//! configuration vector F_c; individuals are scored by the fit score
//! L_MoE/L_MSA (penalized when the combined design overflows the
//! budget, and by the actual pipeline bound so the GA prefers balanced
//! designs). A best-of-num fit ≥ 1 returns early — the MoE block
//! bounds the pipeline.
//! Stage "MoE part 2" (line 11): if the MSA block remains the
//! bottleneck, binary search shrinks the MoE kernel to the smallest
//! configuration that still meets the L_MSA upper bound, minimizing
//! resource usage at unchanged latency.
//!
//! ## Evaluation engine
//!
//! All three stages run on the memoized engine in [`eval`]: the genome
//! factors into an L_MoE table (linear genes), an L_MSA table
//! (num/attention genes) and the resource check, so GA fitness is two
//! array lookups plus arithmetic, with a genome-keyed memo on top.
//! The per-`num` GAs run on scoped threads (each has its own seeded
//! RNG, so parallel-by-`num` is exactly the sequential computation);
//! the Algorithm-1 early exit is preserved by folding outcomes in
//! `num` order and stopping at the first qualifying fit ≥ 1. Results
//! are **bit-identical** to the retained naive evaluator — enforced by
//! `memoized_search_matches_naive_reference` below.
//!
//! [`HasEngine`] exposes the tables for reuse: they depend on the
//! memory fabric but not the budget, so a derate/budget sweep pays the
//! table build once (see `benches/has_search.rs` cold-vs-warm rows).
//!
//! Across *processes*, the search is memoized by the persistent design
//! cache ([`cache`]): the whole design→latency pipeline (search result
//! + operating point + batch-latency surface + expert weight-stream)
//! is content-addressed by its inputs, so warm report sweeps and
//! serving studies perform zero GA evaluations and zero cycle sims.

pub mod binary_search;
pub mod cache;
pub mod eval;
pub mod fleet;
pub mod ga;
pub mod space;

use crate::models::ModelConfig;
use crate::resources::{LinearParams, Platform, Resources};
use crate::sim::memory::{BwAllocation, MemorySystem};
use crate::sim::moe::{ffn_block_cycles, moe_block_cycles, GateHistogram};
use crate::sim::HwChoice;
use eval::{EvalTables, MemoFcGa};
use ga::{GaOutcome, GaParams};
use space::Space;

/// Which return path of Algorithm 1 produced the result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HasStage {
    /// Fit ≥ 1 reached: MoE-bound, returned at line 10.
    BalancedAtMoE,
    /// MSA-bound: MoE shrunk by binary search, returned at line 12.
    MsaBoundMinimized,
}

#[derive(Clone, Debug, PartialEq)]
pub struct HasResult {
    pub hw: HwChoice,
    pub stage: HasStage,
    /// Per-layer block latencies (cycles).
    pub l_msa: f64,
    pub l_moe: f64,
    /// Block-level bound = max(L_MSA, L_MoE) (Fig. 3 double buffering).
    pub l_bound: f64,
    pub fit_score: f64,
    pub resources: Resources,
    /// GA fitness() invocations (memo hits included).
    pub ga_evaluations: usize,
    /// Distinct genomes actually evaluated (memo misses).
    pub ga_true_evaluations: usize,
    /// Fitness calls served from the genome memo.
    pub ga_cache_hits: usize,
    pub ga_history: Vec<f64>,
}

/// Search configuration.
#[derive(Clone, Debug)]
pub struct HasConfig {
    pub space: Space,
    pub ga: GaParams,
    /// Run the per-`num` GAs on scoped threads (bit-identical to the
    /// sequential path; off is useful for profiling/debugging).
    pub parallel: bool,
}

impl HasConfig {
    pub fn paper(q_bits: u32, a_bits: u32) -> HasConfig {
        HasConfig { space: Space::paper(q_bits, a_bits), ga: GaParams::default(), parallel: true }
    }

    /// The deployment-grade search budget shared by the report layer
    /// (Tables I–III) and the serving study: `paper` with the 40-
    /// generation GA both use for production table cells.
    pub fn deployment(q_bits: u32, a_bits: u32) -> HasConfig {
        let mut cfg = HasConfig::paper(q_bits, a_bits);
        cfg.ga.generations = 40;
        cfg
    }
}

/// The "block 2" latency of one encoder pair: the MoE block for MoE
/// models, the dense FFN for plain transformers (the paper: "our
/// design approach effectively accelerates traditional transformer
/// models as well"). For MoE models the *average* encoder block 2 is
/// used (alternate layers are dense), weighted per layer.
pub(crate) fn block2_cycles(
    c: &ModelConfig,
    lin: &LinearParams,
    mem: &MemorySystem,
    share: f64,
) -> f64 {
    if c.num_experts > 0 {
        let h = GateHistogram::balanced(c);
        let moe = moe_block_cycles(c, &h, lin, mem, share);
        let ffn = ffn_block_cycles(c, lin, mem, share);
        let n_moe = c.num_moe_layers() as f64;
        let n_ffn = (c.depth - c.num_moe_layers()) as f64;
        // Weighted per-layer block-2 latency; the MoE component
        // dominates the bound, so also return it for fit scoring via
        // max — the paper balances against the *slowest* block.
        ((moe * n_moe + ffn * n_ffn) / c.depth as f64).max(moe * 0.999)
    } else {
        ffn_block_cycles(c, lin, mem, share)
    }
}

/// Enumerate all feasible linear-kernel configs sorted by DSP usage.
pub(crate) fn linear_candidates(space: &Space) -> Vec<LinearParams> {
    let mut v = Vec::new();
    for &t_in in &space.t_in {
        for &t_out in &space.t_out {
            for &n_l in &space.n_l {
                v.push(LinearParams { t_in, t_out, n_l });
            }
        }
    }
    v.sort_by(|a, b| {
        (a.t_in * a.t_out * a.n_l)
            .cmp(&(b.t_in * b.t_out * b.n_l))
            .then(a.n_l.cmp(&b.n_l))
    });
    v
}

/// A reusable search engine: evaluation tables built once per (model,
/// memory fabric, space). `search()` may then be called repeatedly
/// with different budgets (platform derates) at warm-table cost.
pub struct HasEngine {
    tables: EvalTables,
    cfg: HasConfig,
}

impl HasEngine {
    pub fn new(model: &ModelConfig, platform: &Platform, cfg: &HasConfig) -> HasEngine {
        let fabric = (platform.mem_channels, platform.bw_gbs, platform.freq_mhz);
        let mem = MemorySystem::new(platform.mem_channels, platform.bw_gbs, platform.freq_mhz);
        let bw = BwAllocation::for_channels(platform.mem_channels);
        HasEngine {
            tables: EvalTables::build(model, &cfg.space, mem, bw, fabric),
            cfg: cfg.clone(),
        }
    }

    /// Run Algorithm 1 against `platform`'s budget on the warm tables.
    /// The platform's memory fabric must match the one the engine was
    /// built for (budgets/derates are free to differ).
    pub fn search(&self, platform: &Platform) -> HasResult {
        let fabric = (platform.mem_channels, platform.bw_gbs, platform.freq_mhz);
        assert_eq!(
            self.tables.fabric, fabric,
            "HasEngine was built for a different memory fabric; call HasEngine::new"
        );
        self.search_budget(platform.budget())
    }

    /// [`HasEngine::search`] through the process-global design cache
    /// ([`cache`]): a hit returns the persisted result without any GA
    /// work; a miss searches on the warm tables and persists the full
    /// design artifact. With the cache disabled (the library default)
    /// this is exactly `search`.
    pub fn search_cached(&self, platform: &Platform) -> HasResult {
        let c = cache::DesignCache::global();
        if !c.is_enabled() {
            return self.search(platform);
        }
        let key = cache::design_key(&self.tables.model, platform, &self.cfg);
        if let Some(a) = c.load(&key) {
            return a.has;
        }
        let has = self.search(platform);
        c.store(&key, &cache::artifact_for(&self.tables.model, platform, &has));
        has
    }

    fn search_budget(&self, budget: Resources) -> HasResult {
        let t = &self.tables;
        let model = &t.model;
        let space = &t.space;

        // ---- MoE stage part 1 (line 3): best L_MoE under the DSP
        // budget, reserving a minimal MSA — a filtered table scan.
        let l_moe_target = t.l_moe_target(&budget);
        if !l_moe_target.is_finite() {
            // Platform cannot host even the minimal design (the fixed
            // activation/KV buffers alone may exceed tiny BRAM
            // budgets). Return the minimal point with an infinite
            // bound so callers see a clean infeasibility signal.
            let hw = HwChoice::minimal(space.q_bits, space.a_bits);
            return HasResult {
                hw,
                stage: HasStage::MsaBoundMinimized,
                l_msa: f64::INFINITY,
                l_moe: f64::INFINITY,
                l_bound: f64::INFINITY,
                fit_score: 0.0,
                resources: hw.resources(model.heads, model.patches, model.dim),
                ga_evaluations: 0,
                ga_true_evaluations: 0,
                ga_cache_hits: 0,
                ga_history: Vec::new(),
            };
        }

        // ---- MSA stage (lines 4–10): one GA per `num`. Each GA owns
        // an independent seeded RNG, so running them on scoped threads
        // computes exactly what the sequential loop computes; the
        // fold below replays Algorithm 1's early exit in `num` order,
        // selecting the lowest-`num` qualifying outcome and counting
        // only the evaluations the sequential loop would have paid.
        let run_num = |i: usize| -> (GaOutcome, usize, usize) {
            let problem = MemoFcGa::new(t, i, budget, l_moe_target);
            let out = ga::run(&problem, &self.cfg.ga);
            (out, problem.true_evals(), problem.cache_hits())
        };
        let per_num: Vec<(GaOutcome, usize, usize)> = if self.cfg.parallel && space.num.len() > 1
        {
            std::thread::scope(|s| {
                let run_num = &run_num;
                let handles: Vec<_> =
                    (0..space.num.len()).map(|i| s.spawn(move || run_num(i))).collect();
                handles.into_iter().map(|h| h.join().expect("GA worker panicked")).collect()
            })
        } else {
            // Sequential mode keeps the seed's cost profile: stop
            // spawning GAs as soon as the early-exit condition the
            // fold below applies is already decided.
            let mut v: Vec<(GaOutcome, usize, usize)> = Vec::with_capacity(space.num.len());
            let mut best = f64::NEG_INFINITY;
            for i in 0..space.num.len() {
                let r = run_num(i);
                best = best.max(r.0.best_fitness);
                v.push(r);
                if best >= 1.0 {
                    break;
                }
            }
            v
        };

        let mut overall_best: Option<(usize, GaOutcome)> = None;
        let mut ga_evaluations = 0usize;
        let mut ga_true_evaluations = 0usize;
        let mut ga_cache_hits = 0usize;
        for (i, (out, te, ch)) in per_num.into_iter().enumerate() {
            ga_evaluations += out.evaluations;
            ga_true_evaluations += te;
            ga_cache_hits += ch;
            let better = overall_best
                .as_ref()
                .map(|(_, b)| out.best_fitness > b.best_fitness)
                .unwrap_or(true);
            if better {
                overall_best = Some((i, out));
            }
            if overall_best.as_ref().unwrap().1.best_fitness >= 1.0 {
                break; // Alg. 1 lines 9–10
            }
        }
        let (num_idx, ga_out) = overall_best.expect("non-empty num list");
        let final_problem = MemoFcGa::new(t, num_idx, budget, l_moe_target);
        let (mut hw, l_msa, l_moe_ga, _) = final_problem.eval(&ga_out.best_genome);
        let fit_score = l_moe_target / l_msa;

        if l_moe_ga >= l_msa {
            // MoE-bound: balanced at the MoE latency (Alg. 1 line 10).
            let res = hw.resources(model.heads, model.patches, model.dim);
            return HasResult {
                hw,
                stage: HasStage::BalancedAtMoE,
                l_msa,
                l_moe: l_moe_ga,
                l_bound: l_moe_ga,
                fit_score,
                resources: res,
                ga_evaluations,
                ga_true_evaluations,
                ga_cache_hits,
                ga_history: ga_out.history,
            };
        }

        // ---- MoE stage part 2 (line 11): MSA-bound. Binary-search
        // the smallest (by DSP) linear config whose L_MoE still meets
        // L_MSA and whose combined design fits. The seed evaluated the
        // prefix-any predicate with an O(n) `any` *inside* the binary
        // search — O(n² · eval); here `meets` comes straight from the
        // L_MoE table and the prefix-feasibility array is built once,
        // leaving the binary search O(log n) array probes.
        let feasible: Vec<(LinearParams, usize)> = t
            .candidates
            .iter()
            .copied()
            .filter(|&(_, li)| t.min_msa_res_at(li).fits(&budget))
            .collect();
        let meets: Vec<bool> = feasible
            .iter()
            .map(|&(lin, li)| {
                let hw2 = HwChoice { lin, ..hw };
                hw2.resources(model.heads, model.patches, model.dim).fits(&budget)
                    && t.l_moe_at(li) <= l_msa
            })
            .collect();
        let mut prefix_any = vec![false; meets.len()];
        let mut any = false;
        for (i, &m) in meets.iter().enumerate() {
            any = any || m;
            prefix_any[i] = any;
        }
        let chosen_idx = if feasible.is_empty() {
            None
        } else {
            binary_search::min_satisfying(0, feasible.len() - 1, |idx| prefix_any[idx])
        };
        let mut l_moe_idx = t.lin_index_of(&ga_out.best_genome);
        if let Some(idx) = chosen_idx {
            hw.lin = feasible[idx].0;
            l_moe_idx = feasible[idx].1;
        }
        let l_moe = t.l_moe_at(l_moe_idx);
        let res = hw.resources(model.heads, model.patches, model.dim);

        HasResult {
            hw,
            stage: HasStage::MsaBoundMinimized,
            l_msa,
            l_moe,
            l_bound: l_msa.max(l_moe),
            fit_score,
            resources: res,
            ga_evaluations,
            ga_true_evaluations,
            ga_cache_hits,
            ga_history: ga_out.history,
        }
    }
}

/// Run Algorithm 1 for `model` on `platform` (one-shot: builds the
/// evaluation tables and searches; reuse [`HasEngine`] for sweeps).
pub fn search(model: &ModelConfig, platform: &Platform, cfg: &HasConfig) -> HasResult {
    HasEngine::new(model, platform, cfg).search(platform)
}

/// The seed's direct (table-free, sequential) evaluator, retained as
/// the reference the memoized/parallel engine is equivalence-tested
/// against. Compiled only for tests.
#[cfg(test)]
pub(crate) mod naive {
    use super::ga::GaProblem;
    use super::*;
    use crate::sim::engine::msa_block_cycles_model;

    /// GA problem: full F_c = [T_a, N_a, T_in, T_out, N_L] at fixed
    /// `num`, every fitness a fresh model evaluation.
    struct FcGa<'a> {
        model: &'a ModelConfig,
        space: &'a Space,
        mem: &'a MemorySystem,
        bw: &'a BwAllocation,
        budget: Resources,
        num: usize,
        l_moe_target: f64,
    }

    impl FcGa<'_> {
        fn eval(&self, genome: &[usize]) -> (HwChoice, f64, f64, bool) {
            let hw = self
                .space
                .decode(self.num, &[genome[0], genome[1], genome[2], genome[3], genome[4]]);
            let res = hw.resources(self.model.heads, self.model.patches, self.model.dim);
            if !res.fits(&self.budget) {
                return (hw, f64::INFINITY, f64::INFINITY, false);
            }
            let l_msa = msa_block_cycles_model(self.model, &hw, self.mem, self.bw.msa);
            let l_moe = block2_cycles(self.model, &hw.lin, self.mem, self.bw.moe_weights);
            (hw, l_msa, l_moe, true)
        }
    }

    impl GaProblem for FcGa<'_> {
        fn genes(&self) -> usize {
            Space::GENES
        }

        fn gene_len(&self, gene: usize) -> usize {
            self.space.gene_len(gene)
        }

        fn fitness(&self, genome: &[usize]) -> f64 {
            let (hw, l_msa, l_moe, feasible) = self.eval(genome);
            if !feasible {
                let res = hw.resources(self.model.heads, self.model.patches, self.model.dim);
                return -res.max_util(&self.budget);
            }
            self.l_moe_target / l_msa.max(l_moe)
        }
    }

    pub fn naive_search(model: &ModelConfig, platform: &Platform, cfg: &HasConfig) -> HasResult {
        let budget = platform.budget();
        let mem = MemorySystem::new(platform.mem_channels, platform.bw_gbs, platform.freq_mhz);
        let bw = BwAllocation::for_channels(platform.mem_channels);
        let space = &cfg.space;

        let min_msa = HwChoice::minimal(space.q_bits, space.a_bits);
        let candidates = linear_candidates(space);
        let feasible_with = |lin: &LinearParams| -> bool {
            let hw = HwChoice { lin: *lin, ..min_msa };
            hw.resources(model.heads, model.patches, model.dim).fits(&budget)
        };
        let mut l_moe_target = f64::INFINITY;
        for lin in candidates.iter().filter(|l| feasible_with(l)) {
            let l = block2_cycles(model, lin, &mem, bw.moe_weights);
            if l < l_moe_target {
                l_moe_target = l;
            }
        }
        if !l_moe_target.is_finite() {
            let hw = min_msa;
            return HasResult {
                hw,
                stage: HasStage::MsaBoundMinimized,
                l_msa: f64::INFINITY,
                l_moe: f64::INFINITY,
                l_bound: f64::INFINITY,
                fit_score: 0.0,
                resources: hw.resources(model.heads, model.patches, model.dim),
                ga_evaluations: 0,
                ga_true_evaluations: 0,
                ga_cache_hits: 0,
                ga_history: Vec::new(),
            };
        }

        let mut overall_best: Option<(usize, GaOutcome)> = None;
        let mut total_evals = 0usize;
        for &num in &space.num {
            let problem =
                FcGa { model, space, mem: &mem, bw: &bw, budget, num, l_moe_target };
            let out = ga::run(&problem, &cfg.ga);
            total_evals += out.evaluations;
            let better = overall_best
                .as_ref()
                .map(|(_, b)| out.best_fitness > b.best_fitness)
                .unwrap_or(true);
            if better {
                overall_best = Some((num, out));
            }
            if overall_best.as_ref().unwrap().1.best_fitness >= 1.0 {
                break;
            }
        }
        let (num, ga_out) = overall_best.expect("non-empty num list");
        let problem = FcGa { model, space, mem: &mem, bw: &bw, budget, num, l_moe_target };
        let (mut hw, l_msa, l_moe_ga, _) = problem.eval(&ga_out.best_genome);
        let fit_score = l_moe_target / l_msa;

        if l_moe_ga >= l_msa {
            let res = hw.resources(model.heads, model.patches, model.dim);
            return HasResult {
                hw,
                stage: HasStage::BalancedAtMoE,
                l_msa,
                l_moe: l_moe_ga,
                l_bound: l_moe_ga,
                fit_score,
                resources: res,
                ga_evaluations: total_evals,
                ga_true_evaluations: total_evals,
                ga_cache_hits: 0,
                ga_history: ga_out.history,
            };
        }

        let meets_at = |lin: &LinearParams| -> bool {
            let hw2 = HwChoice { lin: *lin, ..hw };
            hw2.resources(model.heads, model.patches, model.dim).fits(&budget)
                && block2_cycles(model, lin, &mem, bw.moe_weights) <= l_msa
        };
        let feasible: Vec<&LinearParams> =
            candidates.iter().filter(|l| feasible_with(l)).collect();
        let chosen =
            binary_search::min_satisfying(0, feasible.len().saturating_sub(1), |idx| {
                feasible[..=idx].iter().any(|l| meets_at(l))
            })
            .and_then(|idx| feasible[..=idx].iter().find(|l| meets_at(l)).map(|l| **l));
        if let Some(lin) = chosen {
            hw.lin = lin;
        }
        let l_moe = block2_cycles(model, &hw.lin, &mem, bw.moe_weights);
        let res = hw.resources(model.heads, model.patches, model.dim);

        HasResult {
            hw,
            stage: HasStage::MsaBoundMinimized,
            l_msa,
            l_moe,
            l_bound: l_msa.max(l_moe),
            fit_score,
            resources: res,
            ga_evaluations: total_evals,
            ga_true_evaluations: total_evals,
            ga_cache_hits: 0,
            ga_history: ga_out.history,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{bert_b, m3vit_small, vit_s, vit_t};
    use crate::util::proptest::{check, prop_assert};

    fn run_search(model: &ModelConfig, platform: &Platform) -> HasResult {
        let mut cfg = HasConfig::paper(16, 32);
        cfg.ga.generations = 30;
        cfg.ga.population = 40;
        search(model, platform, &cfg)
    }

    #[test]
    fn zcu102_result_fits_budget() {
        let r = run_search(&m3vit_small(), &Platform::zcu102());
        assert!(r.resources.fits(&Platform::zcu102().budget()), "{:?}", r.resources);
        assert!(r.l_bound > 0.0);
    }

    #[test]
    fn search_uses_most_of_the_dsp_budget() {
        // HAS exists to exploit the fabric: the chosen design should
        // not leave the majority of DSPs idle.
        let r = run_search(&m3vit_small(), &Platform::zcu102());
        let budget = Platform::zcu102().budget();
        assert!(
            r.resources.dsp > 0.5 * budget.dsp,
            "only {:.0}/{:.0} DSPs used",
            r.resources.dsp,
            budget.dsp
        );
    }

    #[test]
    fn u280_result_fits_budget_and_beats_zcu102() {
        let z = run_search(&m3vit_small(), &Platform::zcu102());
        let u = run_search(&m3vit_small(), &Platform::u280());
        assert!(u.resources.fits(&Platform::u280().budget()));
        let z_ms = Platform::zcu102().cycles_to_ms(z.l_bound);
        let u_ms = Platform::u280().cycles_to_ms(u.l_bound);
        assert!(u_ms < z_ms, "u280 {u_ms} !< zcu102 {z_ms}");
    }

    #[test]
    fn blocks_are_balanced_after_search() {
        let r = run_search(&m3vit_small(), &Platform::zcu102());
        let ratio = r.l_msa / r.l_moe;
        assert!(
            (0.2..=5.0).contains(&ratio),
            "blocks unbalanced: L_MSA/L_MoE = {ratio} ({:?})",
            r.stage
        );
    }

    #[test]
    fn msa_bound_path_minimizes_moe_resources() {
        let r = run_search(&m3vit_small(), &Platform::zcu102());
        if r.stage == HasStage::MsaBoundMinimized {
            assert!(r.l_moe <= r.l_msa * 1.001, "moe {} msa {}", r.l_moe, r.l_msa);
        } else {
            assert!(r.l_moe >= r.l_msa * 0.999);
        }
    }

    #[test]
    fn works_for_plain_vit() {
        for m in [vit_t(), vit_s()] {
            let r = run_search(&m, &Platform::zcu102());
            assert!(r.resources.fits(&Platform::zcu102().budget()), "{}", m.name);
            assert!(r.l_bound.is_finite() && r.l_bound > 0.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_search(&m3vit_small(), &Platform::zcu102());
        let b = run_search(&m3vit_small(), &Platform::zcu102());
        assert_eq!(a.hw, b.hw);
        assert_eq!(a.stage, b.stage);
    }

    #[test]
    fn parallel_and_sequential_paths_identical() {
        let model = m3vit_small();
        let mut cfg = HasConfig::paper(16, 32);
        cfg.ga.generations = 20;
        cfg.ga.population = 24;
        let par = search(&model, &Platform::zcu102(), &cfg);
        cfg.parallel = false;
        let seq = search(&model, &Platform::zcu102(), &cfg);
        assert_eq!(par.hw, seq.hw);
        assert_eq!(par.stage, seq.stage);
        assert_eq!(par.l_bound, seq.l_bound);
        assert_eq!(par.ga_evaluations, seq.ga_evaluations);
        assert_eq!(par.ga_history, seq.ga_history);
    }

    #[test]
    fn bigger_budget_no_worse() {
        let z = run_search(&m3vit_small(), &Platform::zcu102());
        let u = run_search(&m3vit_small(), &Platform::u280());
        let z_ms = Platform::zcu102().cycles_to_ms(z.l_bound);
        let u_ms = Platform::u280().cycles_to_ms(u.l_bound);
        assert!(u_ms <= z_ms * 1.05, "u {u_ms} z {z_ms}");
    }

    #[test]
    fn memo_accounting_is_consistent() {
        let r = run_search(&m3vit_small(), &Platform::zcu102());
        assert_eq!(
            r.ga_evaluations,
            r.ga_true_evaluations + r.ga_cache_hits,
            "fitness calls must split into true evals + cache hits"
        );
        // A converged GA re-proposes genomes constantly — the memo
        // must actually fire.
        assert!(r.ga_cache_hits > 0, "no cache hits in {} fitness calls", r.ga_evaluations);
        assert!(r.ga_true_evaluations > 0);
    }

    #[test]
    fn engine_reuse_across_derates_matches_fresh_searches() {
        // The tables are budget-independent: a warm engine swept over
        // derates must reproduce fresh per-derate searches exactly.
        let model = m3vit_small();
        let mut cfg = HasConfig::paper(16, 32);
        cfg.ga.generations = 15;
        cfg.ga.population = 24;
        let engine = HasEngine::new(&model, &Platform::zcu102(), &cfg);
        for derate in [0.45, 0.6, 0.75] {
            let mut p = Platform::zcu102();
            p.derate = derate;
            let warm = engine.search(&p);
            let fresh = search(&model, &p, &cfg);
            assert_eq!(warm.hw, fresh.hw, "derate {derate}");
            assert_eq!(warm.stage, fresh.stage, "derate {derate}");
            assert_eq!(warm.l_bound, fresh.l_bound, "derate {derate}");
        }
    }

    #[test]
    #[should_panic(expected = "different memory fabric")]
    fn engine_rejects_foreign_fabric() {
        let cfg = HasConfig::paper(16, 32);
        let engine = HasEngine::new(&m3vit_small(), &Platform::zcu102(), &cfg);
        let _ = engine.search(&Platform::u280());
    }

    #[test]
    fn memoized_search_matches_naive_reference() {
        // The PR's contract: identical HasResult to the seed's direct
        // evaluator across seeds, models and platform derates.
        check(8, |g| {
            let model = match g.usize(0, 2) {
                0 => m3vit_small(),
                1 => vit_t(),
                _ => bert_b(),
            };
            let mut platform = if g.bool() { Platform::zcu102() } else { Platform::u280() };
            platform.derate = *g.pick(&[0.35f64, 0.45, 0.55, 0.75]);
            let mut cfg = HasConfig::paper(16, 32);
            cfg.ga.population = 24;
            cfg.ga.generations = 12;
            cfg.ga.seed = g.u64();
            let fast = search(&model, &platform, &cfg);
            let slow = naive::naive_search(&model, &platform, &cfg);
            let ctx = format!(
                "model={} platform={} derate={} seed={:#x}",
                model.name, platform.name, platform.derate, cfg.ga.seed
            );
            prop_assert(fast.hw == slow.hw, format!("hw: {} vs {} ({ctx})", fast.hw, slow.hw))?;
            prop_assert(
                fast.stage == slow.stage,
                format!("stage: {:?} vs {:?} ({ctx})", fast.stage, slow.stage),
            )?;
            prop_assert(
                fast.l_msa == slow.l_msa && fast.l_moe == slow.l_moe
                    || (fast.l_msa.is_infinite() && slow.l_msa.is_infinite()),
                format!(
                    "latencies: ({}, {}) vs ({}, {}) ({ctx})",
                    fast.l_msa, fast.l_moe, slow.l_msa, slow.l_moe
                ),
            )?;
            prop_assert(
                fast.l_bound == slow.l_bound
                    || (fast.l_bound.is_infinite() && slow.l_bound.is_infinite()),
                format!("l_bound: {} vs {} ({ctx})", fast.l_bound, slow.l_bound),
            )?;
            prop_assert(
                fast.fit_score == slow.fit_score,
                format!("fit: {} vs {} ({ctx})", fast.fit_score, slow.fit_score),
            )?;
            prop_assert(
                fast.resources == slow.resources,
                format!("resources differ ({ctx})"),
            )?;
            prop_assert(
                fast.ga_evaluations == slow.ga_evaluations,
                format!(
                    "evaluations: {} vs {} ({ctx})",
                    fast.ga_evaluations, slow.ga_evaluations
                ),
            )?;
            prop_assert(fast.ga_history == slow.ga_history, format!("history ({ctx})"))
        });
    }
}
