//! 2-stage Hardware Accelerator Search (Algorithm 1).
//!
//! Stage "MoE part 1" (line 3): the best achievable MoE-block latency
//! L_MoE under the platform budget (reserving a minimal MSA) — this is
//! the *target* the MSA stage balances against.
//! Stage "MSA" (lines 4–10): per candidate `num`, a GA searches the
//! configuration vector F_c; individuals are scored by the fit score
//! L_MoE/L_MSA (penalized when the combined design overflows the
//! budget, and by the actual pipeline bound so the GA prefers balanced
//! designs). A best-of-num fit ≥ 1 returns early — the MoE block
//! bounds the pipeline.
//! Stage "MoE part 2" (line 11): if the MSA block remains the
//! bottleneck, binary search shrinks the MoE kernel to the smallest
//! configuration that still meets the L_MSA upper bound, minimizing
//! resource usage at unchanged latency.

pub mod binary_search;
pub mod ga;
pub mod space;

use crate::models::ModelConfig;
use crate::resources::{LinearParams, Platform, Resources};
use crate::sim::engine::msa_block_cycles_model;
use crate::sim::memory::{BwAllocation, MemorySystem};
use crate::sim::moe::{ffn_block_cycles, moe_block_cycles, GateHistogram};
use crate::sim::HwChoice;
use ga::{GaOutcome, GaParams, GaProblem};
use space::Space;

/// Which return path of Algorithm 1 produced the result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HasStage {
    /// Fit ≥ 1 reached: MoE-bound, returned at line 10.
    BalancedAtMoE,
    /// MSA-bound: MoE shrunk by binary search, returned at line 12.
    MsaBoundMinimized,
}

#[derive(Clone, Debug)]
pub struct HasResult {
    pub hw: HwChoice,
    pub stage: HasStage,
    /// Per-layer block latencies (cycles).
    pub l_msa: f64,
    pub l_moe: f64,
    /// Block-level bound = max(L_MSA, L_MoE) (Fig. 3 double buffering).
    pub l_bound: f64,
    pub fit_score: f64,
    pub resources: Resources,
    pub ga_evaluations: usize,
    pub ga_history: Vec<f64>,
}

/// Search configuration.
#[derive(Clone, Debug)]
pub struct HasConfig {
    pub space: Space,
    pub ga: GaParams,
}

impl HasConfig {
    pub fn paper(q_bits: u32, a_bits: u32) -> HasConfig {
        HasConfig { space: Space::paper(q_bits, a_bits), ga: GaParams::default() }
    }
}

/// The "block 2" latency of one encoder pair: the MoE block for MoE
/// models, the dense FFN for plain transformers (the paper: "our
/// design approach effectively accelerates traditional transformer
/// models as well"). For MoE models the *average* encoder block 2 is
/// used (alternate layers are dense), weighted per layer.
fn block2_cycles(c: &ModelConfig, lin: &LinearParams, mem: &MemorySystem, share: f64) -> f64 {
    if c.num_experts > 0 {
        let h = GateHistogram::balanced(c);
        let moe = moe_block_cycles(c, &h, lin, mem, share);
        let ffn = ffn_block_cycles(c, lin, mem, share);
        let n_moe = c.num_moe_layers() as f64;
        let n_ffn = (c.depth - c.num_moe_layers()) as f64;
        // Weighted per-layer block-2 latency; the MoE component
        // dominates the bound, so also return it for fit scoring via
        // max — the paper balances against the *slowest* block.
        ((moe * n_moe + ffn * n_ffn) / c.depth as f64).max(moe * 0.999)
    } else {
        ffn_block_cycles(c, lin, mem, share)
    }
}

/// Enumerate all feasible linear-kernel configs sorted by DSP usage.
fn linear_candidates(space: &Space) -> Vec<LinearParams> {
    let mut v = Vec::new();
    for &t_in in &space.t_in {
        for &t_out in &space.t_out {
            for &n_l in &space.n_l {
                v.push(LinearParams { t_in, t_out, n_l });
            }
        }
    }
    v.sort_by(|a, b| {
        (a.t_in * a.t_out * a.n_l)
            .cmp(&(b.t_in * b.t_out * b.n_l))
            .then(a.n_l.cmp(&b.n_l))
    });
    v
}

/// GA problem: full F_c = [T_a, N_a, T_in, T_out, N_L] at fixed `num`.
struct FcGa<'a> {
    model: &'a ModelConfig,
    space: &'a Space,
    mem: &'a MemorySystem,
    bw: &'a BwAllocation,
    budget: Resources,
    num: usize,
    /// Stage-1 target latency.
    l_moe_target: f64,
}

impl FcGa<'_> {
    fn eval(&self, genome: &[usize]) -> (HwChoice, f64, f64, bool) {
        let hw = self
            .space
            .decode(self.num, &[genome[0], genome[1], genome[2], genome[3], genome[4]]);
        let res = hw.resources(self.model.heads, self.model.patches, self.model.dim);
        if !res.fits(&self.budget) {
            return (hw, f64::INFINITY, f64::INFINITY, false);
        }
        let l_msa = msa_block_cycles_model(self.model, &hw, self.mem, self.bw.msa);
        let l_moe = block2_cycles(self.model, &hw.lin, self.mem, self.bw.moe_weights);
        (hw, l_msa, l_moe, true)
    }
}

impl GaProblem for FcGa<'_> {
    fn genes(&self) -> usize {
        Space::GENES
    }

    fn gene_len(&self, gene: usize) -> usize {
        self.space.gene_len(gene)
    }

    fn fitness(&self, genome: &[usize]) -> f64 {
        let (hw, l_msa, l_moe, feasible) = self.eval(genome);
        if !feasible {
            let res = hw.resources(self.model.heads, self.model.patches, self.model.dim);
            return -res.max_util(&self.budget);
        }
        // Primary objective: minimize the pipeline bound (what HAS is
        // for); expressed as target/bound so the paper's fit score
        // (L_MoE/L_MSA at the target) is ≥ 1 exactly when the MSA
        // block keeps up with the best achievable MoE latency.
        self.l_moe_target / l_msa.max(l_moe)
    }
}

/// Run Algorithm 1 for `model` on `platform`.
pub fn search(model: &ModelConfig, platform: &Platform, cfg: &HasConfig) -> HasResult {
    let budget = platform.budget();
    let mem = MemorySystem::new(platform.mem_channels, platform.bw_gbs, platform.freq_mhz);
    let bw = BwAllocation::for_channels(platform.mem_channels);
    let space = &cfg.space;

    // ---- MoE stage part 1 (line 3): best L_MoE under the DSP budget,
    // reserving a minimal MSA so the design stays realizable.
    let min_msa = HwChoice::minimal(space.q_bits, space.a_bits);
    let candidates = linear_candidates(space);
    let feasible_with = |lin: &LinearParams| -> bool {
        let hw = HwChoice { lin: *lin, ..min_msa };
        hw.resources(model.heads, model.patches, model.dim).fits(&budget)
    };
    let mut l_moe_target = f64::INFINITY;
    for lin in candidates.iter().filter(|l| feasible_with(l)) {
        let l = block2_cycles(model, lin, &mem, bw.moe_weights);
        if l < l_moe_target {
            l_moe_target = l;
        }
    }
    if !l_moe_target.is_finite() {
        // Platform cannot host even the minimal design (the fixed
        // activation/KV buffers alone may exceed tiny BRAM budgets).
        // Return the minimal point with an infinite bound so callers
        // see a clean infeasibility signal instead of GA noise.
        let hw = min_msa;
        return HasResult {
            hw,
            stage: HasStage::MsaBoundMinimized,
            l_msa: f64::INFINITY,
            l_moe: f64::INFINITY,
            l_bound: f64::INFINITY,
            fit_score: 0.0,
            resources: hw.resources(model.heads, model.patches, model.dim),
            ga_evaluations: 0,
            ga_history: Vec::new(),
        };
    }

    // ---- MSA stage (lines 4–10): GA per `num`, early exit at fit ≥ 1.
    let mut overall_best: Option<(usize, GaOutcome)> = None;
    let mut total_evals = 0usize;
    for &num in &space.num {
        let problem = FcGa {
            model,
            space,
            mem: &mem,
            bw: &bw,
            budget,
            num,
            l_moe_target,
        };
        let out = ga::run(&problem, &cfg.ga);
        total_evals += out.evaluations;
        let better = overall_best
            .as_ref()
            .map(|(_, b)| out.best_fitness > b.best_fitness)
            .unwrap_or(true);
        if better {
            overall_best = Some((num, out));
        }
        if overall_best.as_ref().unwrap().1.best_fitness >= 1.0 {
            break; // Alg. 1 lines 9–10
        }
    }
    let (num, ga_out) = overall_best.expect("non-empty num list");
    let problem = FcGa {
        model,
        space,
        mem: &mem,
        bw: &bw,
        budget,
        num,
        l_moe_target,
    };
    let (mut hw, l_msa, l_moe_ga, _) = problem.eval(&ga_out.best_genome);
    let fit_score = l_moe_target / l_msa;

    if l_moe_ga >= l_msa {
        // MoE-bound: balanced at the MoE latency (Alg. 1 line 10).
        let res = hw.resources(model.heads, model.patches, model.dim);
        return HasResult {
            hw,
            stage: HasStage::BalancedAtMoE,
            l_msa,
            l_moe: l_moe_ga,
            l_bound: l_moe_ga,
            fit_score,
            resources: res,
            ga_evaluations: total_evals,
            ga_history: ga_out.history,
        };
    }

    // ---- MoE stage part 2 (line 11): MSA-bound. Binary-search the
    // smallest (by DSP) linear config whose L_MoE still meets L_MSA
    // and whose combined design fits — freeing resources at unchanged
    // pipeline latency.
    let meets_at = |lin: &LinearParams| -> bool {
        let hw2 = HwChoice { lin: *lin, ..hw };
        hw2.resources(model.heads, model.patches, model.dim).fits(&budget)
            && block2_cycles(model, lin, &mem, bw.moe_weights) <= l_msa
    };
    let feasible: Vec<&LinearParams> = candidates.iter().filter(|l| feasible_with(l)).collect();
    let chosen = binary_search::min_satisfying(0, feasible.len().saturating_sub(1), |idx| {
        // prefix predicate: some config at or below idx meets the bound
        feasible[..=idx].iter().any(|l| meets_at(l))
    })
    .and_then(|idx| feasible[..=idx].iter().find(|l| meets_at(l)).map(|l| **l));
    if let Some(lin) = chosen {
        hw.lin = lin;
    }
    let l_moe = block2_cycles(model, &hw.lin, &mem, bw.moe_weights);
    let res = hw.resources(model.heads, model.patches, model.dim);

    HasResult {
        hw,
        stage: HasStage::MsaBoundMinimized,
        l_msa,
        l_moe,
        l_bound: l_msa.max(l_moe),
        fit_score,
        resources: res,
        ga_evaluations: total_evals,
        ga_history: ga_out.history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{m3vit_small, vit_s, vit_t};

    fn run_search(model: &ModelConfig, platform: &Platform) -> HasResult {
        let mut cfg = HasConfig::paper(16, 32);
        cfg.ga.generations = 30;
        cfg.ga.population = 40;
        search(model, platform, &cfg)
    }

    #[test]
    fn zcu102_result_fits_budget() {
        let r = run_search(&m3vit_small(), &Platform::zcu102());
        assert!(r.resources.fits(&Platform::zcu102().budget()), "{:?}", r.resources);
        assert!(r.l_bound > 0.0);
    }

    #[test]
    fn search_uses_most_of_the_dsp_budget() {
        // HAS exists to exploit the fabric: the chosen design should
        // not leave the majority of DSPs idle.
        let r = run_search(&m3vit_small(), &Platform::zcu102());
        let budget = Platform::zcu102().budget();
        assert!(
            r.resources.dsp > 0.5 * budget.dsp,
            "only {:.0}/{:.0} DSPs used",
            r.resources.dsp,
            budget.dsp
        );
    }

    #[test]
    fn u280_result_fits_budget_and_beats_zcu102() {
        let z = run_search(&m3vit_small(), &Platform::zcu102());
        let u = run_search(&m3vit_small(), &Platform::u280());
        assert!(u.resources.fits(&Platform::u280().budget()));
        let z_ms = Platform::zcu102().cycles_to_ms(z.l_bound);
        let u_ms = Platform::u280().cycles_to_ms(u.l_bound);
        assert!(u_ms < z_ms, "u280 {u_ms} !< zcu102 {z_ms}");
    }

    #[test]
    fn blocks_are_balanced_after_search() {
        let r = run_search(&m3vit_small(), &Platform::zcu102());
        let ratio = r.l_msa / r.l_moe;
        assert!(
            (0.2..=5.0).contains(&ratio),
            "blocks unbalanced: L_MSA/L_MoE = {ratio} ({:?})",
            r.stage
        );
    }

    #[test]
    fn msa_bound_path_minimizes_moe_resources() {
        let r = run_search(&m3vit_small(), &Platform::zcu102());
        if r.stage == HasStage::MsaBoundMinimized {
            assert!(r.l_moe <= r.l_msa * 1.001, "moe {} msa {}", r.l_moe, r.l_msa);
        } else {
            assert!(r.l_moe >= r.l_msa * 0.999);
        }
    }

    #[test]
    fn works_for_plain_vit() {
        for m in [vit_t(), vit_s()] {
            let r = run_search(&m, &Platform::zcu102());
            assert!(r.resources.fits(&Platform::zcu102().budget()), "{}", m.name);
            assert!(r.l_bound.is_finite() && r.l_bound > 0.0);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_search(&m3vit_small(), &Platform::zcu102());
        let b = run_search(&m3vit_small(), &Platform::zcu102());
        assert_eq!(a.hw, b.hw);
        assert_eq!(a.stage, b.stage);
    }

    #[test]
    fn bigger_budget_no_worse() {
        let z = run_search(&m3vit_small(), &Platform::zcu102());
        let u = run_search(&m3vit_small(), &Platform::u280());
        let z_ms = Platform::zcu102().cycles_to_ms(z.l_bound);
        let u_ms = Platform::u280().cycles_to_ms(u.l_bound);
        assert!(u_ms <= z_ms * 1.05, "u {u_ms} z {z_ms}");
    }
}
