//! Fleet↔hardware co-design search: the `has/ga.rs` machinery one
//! level up (the top ROADMAP open item, following the co-design
//! framing of CHOSEN and CoQMoE from PAPERS.md).
//!
//! Where Algorithm 1 tunes *one accelerator* for *one platform*, this
//! module searches over *fleet compositions*: how many devices of each
//! platform template, at which bit-width tier, behind which
//! [`DispatchPolicy`], with which autoscaler constants — scored not by
//! a single-device latency model but by whole serving-DES runs
//! ([`crate::serve::simulate_fleet`]) over a scenario grid. Three
//! objectives come back per candidate:
//!
//! * **device-seconds** — integrated fleet availability, the cost side
//!   ([`crate::serve::FleetReport::device_seconds`], summed over the
//!   grid);
//! * **p99 ms** — worst end-to-end tail across the grid's scenarios;
//! * **energy J** — device-seconds × mean board watts per device, the
//!   [`crate::sim::power::design_power`] estimate attached to each
//!   template variant. Exact for static fleets (every device is up for
//!   the same span); autoscaled candidates are restricted to
//!   homogeneous compositions, where it is exact per activation too.
//!
//! A thousand-point search is affordable because fitness never runs
//! the event loop twice for the same `(ServeConfig, seed)`: every DES
//! run goes through the whole-report memo
//! ([`crate::has::cache::DesignCache::get_or_compute_fleet`], keyed by
//! [`crate::serve::ServeConfig::canonical_key`]), plus an in-process
//! genome archive so the GA's revisits are free. A memo-warm
//! [`plan_fleet`] rerun therefore performs **zero** DES event loops
//! (counter-asserted via [`crate::obs::registry`] in
//! `rust/tests/fleet_cache.rs` and CI).
//!
//! Tiny search spaces (≤ [`EXHAUSTIVE_LIMIT`] genomes) are enumerated
//! outright — deterministic, and the returned frontier is then the
//! *true* Pareto set, which is what makes the `plan_small` golden
//! hand-checkable. Larger spaces run one GA per scalarization weight
//! profile (seeded `ga.seed + profile index`), all profiles sharing
//! the archive; the frontier is the non-dominated subset of every
//! candidate any profile evaluated. Either way the outcome is a pure
//! function of `(spec, seed)` — bit-identical across reruns
//! (proptested in `rust/tests/plan_properties.rs`).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::time::Duration;

use crate::has::cache::DesignCache;
use crate::has::ga::{self, GaParams, GaProblem};
use crate::serve::autoscale::AutoscaleConfig;
use crate::serve::device::DeviceModel;
use crate::serve::dispatch::DispatchPolicy;
use crate::serve::{ServeConfig, ServeConfigError, Workload};

/// Genome spaces at or below this size are enumerated exhaustively
/// instead of GA-sampled: deterministic, complete, and cheap (each
/// distinct candidate is one archive entry; DES runs are memoized).
pub const EXHAUSTIVE_LIMIT: usize = 512;

/// Penalty fitness for infeasible genomes (empty fleet, heterogeneous
/// autoscale, or a config `validate()` rejects).
const INFEASIBLE: f64 = -1e30;

/// One bit-width tier of a platform template: the costed device plus
/// its board-power estimate (`sim/power.rs::design_power` for
/// cycle-model-backed designs; explicit for synthetic test devices).
#[derive(Clone, Debug)]
pub struct PlanVariant {
    /// Tier label, e.g. `"w16"` / `"w8"`.
    pub label: String,
    pub device: DeviceModel,
    /// Mean board power of one device of this tier, watts.
    pub watts: f64,
}

/// A platform template the planner may instantiate 0..=`max_count`
/// times, at exactly one of its bit-width `variants`.
#[derive(Clone, Debug)]
pub struct PlanTemplate {
    pub name: String,
    pub variants: Vec<PlanVariant>,
    pub max_count: usize,
}

/// Autoscaler-constant preset the genome may attach to a homogeneous
/// composition. Applied over [`AutoscaleConfig::for_device`] of the
/// composition's template device; the SLO defended is `slo_factor` ×
/// that device's largest-batch service time (the
/// `report::serving::attainable_slo` convention).
#[derive(Clone, Debug)]
pub struct AutoscalePreset {
    pub label: String,
    pub slo_factor: u32,
    pub rho_target: f64,
    pub target_attainment: f64,
    pub scale_down_patience: u32,
    pub min_devices: usize,
    pub max_devices: usize,
}

/// One point of the scenario grid fitness averages over: a workload
/// shape at a horizon and seed.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub label: String,
    pub workload: Workload,
    pub horizon: Duration,
    pub seed: u64,
}

/// The whole planning problem: what may be composed, what traffic it
/// must serve, and the search budget.
#[derive(Clone, Debug)]
pub struct FleetSpec {
    pub name: String,
    pub templates: Vec<PlanTemplate>,
    pub scenarios: Vec<Scenario>,
    pub policies: Vec<DispatchPolicy>,
    pub autoscale_presets: Vec<AutoscalePreset>,
    /// Expert count of the served model (dominant-expert hint stream;
    /// 0 for plain transformers).
    pub num_experts: usize,
    pub ga: GaParams,
    /// Scalarization weight profiles over (device-seconds, p99,
    /// energy); one GA run each. Empty falls back to `[1, 1, 1]`.
    pub weight_profiles: Vec<[f64; 3]>,
}

impl FleetSpec {
    /// Cross-field plan-path validation (the `ServeConfig::validate`
    /// extension of ISSUE 10): a spec that passes here never panics
    /// inside the DES or the autoscale controller asserts.
    pub fn validate(&self) -> Result<(), ServeConfigError> {
        let usable = self
            .templates
            .iter()
            .any(|t| t.max_count >= 1 && !t.variants.is_empty());
        if self.templates.is_empty() || !usable {
            return Err(ServeConfigError::PlanEmptyTemplates);
        }
        if self.scenarios.is_empty() || self.policies.is_empty() {
            return Err(ServeConfigError::PlanEmptyScenarioGrid);
        }
        for p in &self.autoscale_presets {
            if p.slo_factor == 0 {
                return Err(ServeConfigError::PlanAutoscaleBounds("slo_factor"));
            }
            if !(p.rho_target > 0.0 && p.rho_target <= 1.0) {
                return Err(ServeConfigError::PlanAutoscaleBounds("rho_target"));
            }
            if !(p.target_attainment > 0.0 && p.target_attainment <= 1.0) {
                return Err(ServeConfigError::PlanAutoscaleBounds("target_attainment"));
            }
            if p.scale_down_patience == 0 {
                return Err(ServeConfigError::PlanAutoscaleBounds("scale_down_patience"));
            }
            if p.min_devices == 0 {
                return Err(ServeConfigError::PlanAutoscaleBounds("min_devices"));
            }
            if p.max_devices < p.min_devices {
                return Err(ServeConfigError::PlanAutoscaleBounds("max_devices"));
            }
        }
        Ok(())
    }

    /// Genome layout: for T templates — genes `0..T` are per-template
    /// counts (`0..=max_count`), genes `T..2T` the variant index, gene
    /// `2T` the dispatch-policy index, gene `2T+1` the autoscale
    /// choice (0 = none, k = preset k−1).
    pub fn genes(&self) -> usize {
        2 * self.templates.len() + 2
    }

    fn gene_len(&self, gene: usize) -> usize {
        let t = self.templates.len();
        if gene < t {
            self.templates[gene].max_count + 1
        } else if gene < 2 * t {
            self.templates[gene - t].variants.len()
        } else if gene == 2 * t {
            self.policies.len()
        } else {
            self.autoscale_presets.len() + 1
        }
    }

    /// Total genome-space size (Π gene cardinalities, saturating).
    pub fn space_size(&self) -> usize {
        (0..self.genes()).fold(1usize, |acc, g| acc.saturating_mul(self.gene_len(g)))
    }

    /// Canonical genome: variant genes of zero-count templates are
    /// don't-cares, forced to 0 so equal candidates share one archive
    /// entry (and one frontier row).
    fn canonical(&self, genome: &[usize]) -> Vec<usize> {
        let t = self.templates.len();
        let mut g = genome.to_vec();
        for i in 0..t {
            if g[i] == 0 {
                g[t + i] = 0;
            }
        }
        g
    }

    fn decode(&self, genome: &[usize]) -> Candidate {
        let t = self.templates.len();
        Candidate {
            counts: genome[..t].to_vec(),
            variants: genome[t..2 * t].to_vec(),
            policy: genome[2 * t],
            autoscale: genome[2 * t + 1].checked_sub(1),
        }
    }
}

/// A decoded genome: the fleet composition the DES will cost.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Candidate {
    /// Device count per template (0 = template unused).
    pub counts: Vec<usize>,
    /// Chosen variant index per template.
    pub variants: Vec<usize>,
    /// Index into [`FleetSpec::policies`].
    pub policy: usize,
    /// `Some(i)` = [`FleetSpec::autoscale_presets`]`[i]`, `None` =
    /// static fleet.
    pub autoscale: Option<usize>,
}

impl Candidate {
    /// Composition label, e.g. `"2xzcu102/w8+1xu280/w16"`.
    pub fn label(&self, spec: &FleetSpec) -> String {
        let parts: Vec<String> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                let t = &spec.templates[i];
                format!("{c}x{}/{}", t.name, t.variants[self.variants[i]].label)
            })
            .collect();
        parts.join("+")
    }

    /// Scale-mode label: `"static"` or the preset's label.
    pub fn scale_label(&self, spec: &FleetSpec) -> String {
        match self.autoscale {
            None => "static".to_string(),
            Some(i) => spec.autoscale_presets[i].label.clone(),
        }
    }
}

/// The three minimized objectives of one candidate over the whole
/// scenario grid.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanObjectives {
    /// Σ device-seconds over the grid.
    pub device_seconds: f64,
    /// max fleet-wide end-to-end p99 over the grid, ms.
    pub p99_ms: f64,
    /// Σ device-seconds × mean watts per device, joules.
    pub energy_j: f64,
}

impl PlanObjectives {
    /// Strict Pareto dominance (minimization): ≤ on every objective
    /// and < on at least one.
    pub fn dominates(&self, other: &PlanObjectives) -> bool {
        let le = self.device_seconds <= other.device_seconds
            && self.p99_ms <= other.p99_ms
            && self.energy_j <= other.energy_j;
        let lt = self.device_seconds < other.device_seconds
            || self.p99_ms < other.p99_ms
            || self.energy_j < other.energy_j;
        le && lt
    }

    fn bits(&self) -> [u64; 3] {
        [
            self.device_seconds.to_bits(),
            self.p99_ms.to_bits(),
            self.energy_j.to_bits(),
        ]
    }
}

/// One non-dominated plan.
#[derive(Clone, Debug)]
pub struct FrontierPoint {
    pub candidate: Candidate,
    pub objectives: PlanObjectives,
}

/// Everything [`plan_fleet`] found.
#[derive(Clone, Debug)]
pub struct FleetPlanOutcome {
    /// Non-dominated candidates, sorted by (device-seconds, p99,
    /// energy, genome) — deterministic presentation order.
    pub frontier: Vec<FrontierPoint>,
    /// Distinct candidates costed through the DES (archive size).
    pub evaluated: usize,
    /// Of those, how many were feasible.
    pub feasible: usize,
    /// Genome-space size.
    pub space: usize,
    /// True iff the space fit under [`EXHAUSTIVE_LIMIT`] and was
    /// enumerated instead of GA-sampled.
    pub exhaustive: bool,
    /// Σ GA `fitness()` invocations across weight-profile runs (0 in
    /// exhaustive mode).
    pub ga_evaluations: usize,
}

/// Materialize the per-scenario [`ServeConfig`]s (and the mean board
/// watts per device) a candidate's fitness aggregates over, or `None`
/// if the candidate is structurally infeasible (empty fleet, or an
/// autoscale preset on a heterogeneous composition — autoscaling
/// clones one template, so heterogeneous scaling is ill-posed).
///
/// Public so tests and the `ubimoe plan` replay path can rebuild the
/// *exact* configs the search costed and reconcile frontier objectives
/// against an independent cold [`crate::serve::simulate_fleet`] run
/// (satellite 2 of ISSUE 10).
pub fn fleet_configs(spec: &FleetSpec, cand: &Candidate) -> Option<(Vec<ServeConfig>, f64)> {
    let total: usize = cand.counts.iter().sum();
    if total == 0 {
        return None;
    }
    let active: Vec<usize> = (0..cand.counts.len()).filter(|&i| cand.counts[i] > 0).collect();
    if cand.autoscale.is_some() && active.len() != 1 {
        return None;
    }
    let mut devices = Vec::with_capacity(total);
    let mut watts_total = 0.0;
    for &i in &active {
        let v = &spec.templates[i].variants[cand.variants[i]];
        for _ in 0..cand.counts[i] {
            devices.push(v.device.clone());
        }
        watts_total += cand.counts[i] as f64 * v.watts;
    }
    let mean_watts = watts_total / total as f64;

    let mut cfgs = Vec::with_capacity(spec.scenarios.len());
    for sc in &spec.scenarios {
        let mut cfg = ServeConfig::mixed(devices.clone(), sc.workload.clone());
        cfg.dispatch = spec.policies[cand.policy];
        cfg.horizon = sc.horizon;
        cfg.seed = sc.seed;
        cfg.num_experts = spec.num_experts;
        if let Some(p) = cand.autoscale {
            let preset = &spec.autoscale_presets[p];
            let template = &spec.templates[active[0]].variants[cand.variants[active[0]]];
            let largest =
                *template.device.batch_sizes.last().expect("device with no batch sizes");
            let slo = template.device.service_time(largest) * preset.slo_factor;
            let mut ac = AutoscaleConfig::for_device(template.device.clone(), slo);
            ac.rho_target = preset.rho_target;
            ac.target_attainment = preset.target_attainment;
            ac.scale_down_patience = preset.scale_down_patience;
            ac.min_devices = preset.min_devices;
            ac.max_devices = preset.max_devices;
            cfg.autoscale = Some(ac);
        }
        cfgs.push(cfg);
    }
    Some((cfgs, mean_watts))
}

/// Fold a scenario grid's [`crate::serve::FleetReport`]s into the three
/// plan objectives — the single place the objective arithmetic lives,
/// shared by the search fitness and the reconciliation replay.
pub fn objectives_from_reports(
    reports: &[crate::serve::FleetReport],
    mean_watts: f64,
) -> PlanObjectives {
    let mut obj = PlanObjectives { device_seconds: 0.0, p99_ms: 0.0, energy_j: 0.0 };
    for r in reports {
        obj.device_seconds += r.device_seconds;
        obj.p99_ms = obj.p99_ms.max(r.fleet.e2e.p99().as_secs_f64() * 1e3);
        obj.energy_j += r.device_seconds * mean_watts;
    }
    obj
}

/// The [`GaProblem`] adapter: genome → composition → memoized DES runs
/// → weighted scalarization. `archive` is shared across weight-profile
/// runs so a candidate is costed at most once per process (and the DES
/// itself at most once per cache lifetime).
struct FleetProblem<'a> {
    spec: &'a FleetSpec,
    cache: &'a DesignCache,
    archive: &'a RefCell<BTreeMap<Vec<usize>, Option<PlanObjectives>>>,
    /// Normalization reference (the all-templates-×1 baseline), so the
    /// weight profiles act on comparable magnitudes.
    reference: PlanObjectives,
    weights: [f64; 3],
}

impl FleetProblem<'_> {
    /// Cost one candidate over the scenario grid, or `None` if it is
    /// infeasible. Every DES run goes through the fleet-report memo.
    fn evaluate(&self, cand: &Candidate) -> Option<PlanObjectives> {
        let (cfgs, mean_watts) = fleet_configs(self.spec, cand)?;
        let mut reports = Vec::with_capacity(cfgs.len());
        for cfg in &cfgs {
            if cfg.validate().is_err() {
                return None;
            }
            reports.push(self.cache.get_or_compute_fleet(cfg));
        }
        Some(objectives_from_reports(&reports, mean_watts))
    }

    fn objectives_for(&self, genome: &[usize]) -> Option<PlanObjectives> {
        let key = self.spec.canonical(genome);
        if let Some(cached) = self.archive.borrow().get(&key) {
            return *cached;
        }
        let obj = self.evaluate(&self.spec.decode(&key));
        self.archive.borrow_mut().insert(key, obj);
        obj
    }
}

impl GaProblem for FleetProblem<'_> {
    fn genes(&self) -> usize {
        self.spec.genes()
    }

    fn gene_len(&self, gene: usize) -> usize {
        self.spec.gene_len(gene)
    }

    fn fitness(&self, genome: &[usize]) -> f64 {
        match self.objectives_for(genome) {
            None => INFEASIBLE,
            Some(o) => {
                let r = &self.reference;
                -(self.weights[0] * o.device_seconds / r.device_seconds.max(1e-12)
                    + self.weights[1] * o.p99_ms / r.p99_ms.max(1e-12)
                    + self.weights[2] * o.energy_j / r.energy_j.max(1e-12))
            }
        }
    }
}

/// Run the fleet-composition search and return the Pareto frontier
/// over (device-seconds, p99, energy). Deterministic per `(spec,
/// seeds)`: warm reruns hit the fleet-report memo for every DES run
/// the search needs.
pub fn plan_fleet(
    spec: &FleetSpec,
    cache: &DesignCache,
) -> Result<FleetPlanOutcome, ServeConfigError> {
    spec.validate()?;
    let archive = RefCell::new(BTreeMap::new());

    // Normalization reference: one device of every template's first
    // variant, first policy, static — evaluated through the same
    // memoized path (it lands in the archive, so it competes for the
    // frontier like any other candidate).
    let mut baseline = vec![0usize; spec.genes()];
    for (i, tpl) in spec.templates.iter().enumerate() {
        baseline[i] = usize::from(tpl.max_count >= 1 && !tpl.variants.is_empty());
    }
    let bootstrap = FleetProblem {
        spec,
        cache,
        archive: &archive,
        reference: PlanObjectives { device_seconds: 1.0, p99_ms: 1.0, energy_j: 1.0 },
        weights: [1.0, 1.0, 1.0],
    };
    let reference = bootstrap
        .objectives_for(&baseline)
        .unwrap_or(PlanObjectives { device_seconds: 1.0, p99_ms: 1.0, energy_j: 1.0 });

    let space = spec.space_size();
    let exhaustive = space <= EXHAUSTIVE_LIMIT;
    let mut ga_evaluations = 0usize;
    if exhaustive {
        // Odometer over the whole genome space: complete, so the
        // frontier below is the true Pareto set.
        let mut genome = vec![0usize; spec.genes()];
        loop {
            let _ = bootstrap.objectives_for(&genome);
            let mut g = 0;
            loop {
                if g == genome.len() {
                    break;
                }
                genome[g] += 1;
                if genome[g] < spec.gene_len(g) {
                    break;
                }
                genome[g] = 0;
                g += 1;
            }
            if g == genome.len() {
                break;
            }
        }
    } else {
        let profiles: &[[f64; 3]] = if spec.weight_profiles.is_empty() {
            &[[1.0, 1.0, 1.0]]
        } else {
            &spec.weight_profiles
        };
        for (i, w) in profiles.iter().enumerate() {
            let problem = FleetProblem {
                spec,
                cache,
                archive: &archive,
                reference,
                weights: *w,
            };
            let params = GaParams { seed: spec.ga.seed.wrapping_add(i as u64), ..spec.ga };
            let out = ga::run(&problem, &params);
            ga_evaluations += out.evaluations;
        }
    }

    let archive = archive.into_inner();
    let evaluated = archive.len();
    let mut feasible: Vec<(Vec<usize>, PlanObjectives)> = archive
        .into_iter()
        .filter_map(|(g, o)| o.map(|o| (g, o)))
        .collect();
    let n_feasible = feasible.len();
    // Identical objective triples (e.g. every policy on a 1-device
    // fleet) collapse to the lexicographically smallest genome.
    feasible.sort_by(|a, b| a.1.bits().cmp(&b.1.bits()).then_with(|| a.0.cmp(&b.0)));
    feasible.dedup_by(|a, b| a.1.bits() == b.1.bits());

    let mut frontier: Vec<FrontierPoint> = feasible
        .iter()
        .filter(|(_, o)| !feasible.iter().any(|(_, other)| other.dominates(o)))
        .map(|(g, o)| FrontierPoint { candidate: spec.decode(g), objectives: *o })
        .collect();
    frontier.sort_by(|a, b| {
        a.objectives
            .device_seconds
            .total_cmp(&b.objectives.device_seconds)
            .then(a.objectives.p99_ms.total_cmp(&b.objectives.p99_ms))
            .then(a.objectives.energy_j.total_cmp(&b.objectives.energy_j))
            .then_with(|| a.candidate.counts.cmp(&b.candidate.counts))
    });

    Ok(FleetPlanOutcome {
        frontier,
        evaluated,
        feasible: n_feasible,
        space,
        exhaustive,
        ga_evaluations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev(name: &str, fill_ms: u64, period_ms: u64) -> DeviceModel {
        DeviceModel::from_latencies(
            name.into(),
            Duration::from_millis(fill_ms),
            Duration::from_millis(period_ms),
            &[1],
        )
    }

    fn tiny_spec() -> FleetSpec {
        FleetSpec {
            name: "tiny".into(),
            templates: vec![
                PlanTemplate {
                    name: "edge".into(),
                    variants: vec![PlanVariant {
                        label: "w16".into(),
                        device: dev("edge", 1, 2),
                        watts: 5.0,
                    }],
                    max_count: 1,
                },
                PlanTemplate {
                    name: "core".into(),
                    variants: vec![PlanVariant {
                        label: "w16".into(),
                        device: dev("core", 1, 1),
                        watts: 9.0,
                    }],
                    max_count: 1,
                },
            ],
            scenarios: vec![Scenario {
                label: "trace".into(),
                workload: Workload::Trace {
                    arrivals: vec![
                        Duration::from_millis(0),
                        Duration::from_millis(1),
                        Duration::from_millis(2),
                        Duration::from_millis(3),
                    ],
                },
                horizon: Duration::from_millis(20),
                seed: 7,
            }],
            policies: vec![DispatchPolicy::JoinShortestQueue],
            autoscale_presets: vec![],
            num_experts: 0,
            ga: GaParams::default(),
            weight_profiles: vec![[1.0, 1.0, 1.0]],
        }
    }

    #[test]
    fn tiny_space_is_exhaustive_and_frontier_is_hand_checkable() {
        let spec = tiny_spec();
        assert_eq!(spec.space_size(), 4);
        let out = plan_fleet(&spec, &DesignCache::disabled()).unwrap();
        assert!(out.exhaustive);
        assert_eq!(out.ga_evaluations, 0);
        // Empty composition is the one infeasible genome.
        assert_eq!(out.evaluated, 4);
        assert_eq!(out.feasible, 3);
        // Hand-computed (see report::plan::small_spec docs): all three
        // compositions are mutually non-dominated.
        assert_eq!(out.frontier.len(), 3);
        let o = &out.frontier[0].objectives;
        // {core}: horizon-bound span 20 ms, worst e2e 5 ms, 9 W.
        assert!((o.device_seconds - 0.020).abs() < 1e-12, "{o:?}");
        assert!((o.p99_ms - 5.0).abs() < 1e-9, "{o:?}");
        assert!((o.energy_j - 0.180).abs() < 1e-9, "{o:?}");
        let o = &out.frontier[1].objectives;
        // {edge}: 20 ms span, worst e2e 9 ms, 5 W.
        assert!((o.p99_ms - 9.0).abs() < 1e-9, "{o:?}");
        assert!((o.energy_j - 0.100).abs() < 1e-9, "{o:?}");
        let o = &out.frontier[2].objectives;
        // {edge, core}: 2 × 20 ms, worst e2e 4 ms, mean 7 W.
        assert!((o.device_seconds - 0.040).abs() < 1e-12, "{o:?}");
        assert!((o.p99_ms - 4.0).abs() < 1e-9, "{o:?}");
        assert!((o.energy_j - 0.280).abs() < 1e-9, "{o:?}");
        // Labels render deterministically.
        assert_eq!(out.frontier[0].candidate.label(&spec), "1xcore/w16");
        assert_eq!(out.frontier[2].candidate.label(&spec), "1xedge/w16+1xcore/w16");
        assert_eq!(out.frontier[0].candidate.scale_label(&spec), "static");
    }

    #[test]
    fn plan_is_deterministic() {
        let spec = tiny_spec();
        let a = plan_fleet(&spec, &DesignCache::disabled()).unwrap();
        let b = plan_fleet(&spec, &DesignCache::disabled()).unwrap();
        assert_eq!(a.frontier.len(), b.frontier.len());
        for (x, y) in a.frontier.iter().zip(&b.frontier) {
            assert_eq!(x.candidate, y.candidate);
            assert_eq!(x.objectives.bits(), y.objectives.bits());
        }
    }

    #[test]
    fn validate_rejects_degenerate_specs() {
        let mut s = tiny_spec();
        s.templates.clear();
        assert_eq!(s.validate(), Err(ServeConfigError::PlanEmptyTemplates));

        let mut s = tiny_spec();
        for t in &mut s.templates {
            t.max_count = 0;
        }
        assert_eq!(s.validate(), Err(ServeConfigError::PlanEmptyTemplates));

        let mut s = tiny_spec();
        s.scenarios.clear();
        assert_eq!(s.validate(), Err(ServeConfigError::PlanEmptyScenarioGrid));

        let mut s = tiny_spec();
        s.policies.clear();
        assert_eq!(s.validate(), Err(ServeConfigError::PlanEmptyScenarioGrid));

        let preset = AutoscalePreset {
            label: "as".into(),
            slo_factor: 3,
            rho_target: 0.7,
            target_attainment: 0.99,
            scale_down_patience: 2,
            min_devices: 1,
            max_devices: 4,
        };
        for (field, mutate) in [
            ("slo_factor", Box::new(|p: &mut AutoscalePreset| p.slo_factor = 0)
                as Box<dyn Fn(&mut AutoscalePreset)>),
            ("rho_target", Box::new(|p: &mut AutoscalePreset| p.rho_target = 0.0)),
            ("rho_target", Box::new(|p: &mut AutoscalePreset| p.rho_target = 1.5)),
            (
                "target_attainment",
                Box::new(|p: &mut AutoscalePreset| p.target_attainment = 0.0),
            ),
            (
                "scale_down_patience",
                Box::new(|p: &mut AutoscalePreset| p.scale_down_patience = 0),
            ),
            ("min_devices", Box::new(|p: &mut AutoscalePreset| p.min_devices = 0)),
            (
                "max_devices",
                Box::new(|p: &mut AutoscalePreset| {
                    p.min_devices = 3;
                    p.max_devices = 2;
                }),
            ),
        ] {
            let mut s = tiny_spec();
            let mut p = preset.clone();
            mutate(&mut p);
            s.autoscale_presets = vec![p];
            assert_eq!(
                s.validate(),
                Err(ServeConfigError::PlanAutoscaleBounds(field)),
                "{field}"
            );
        }
        // The untouched preset passes.
        let mut s = tiny_spec();
        s.autoscale_presets = vec![preset];
        assert!(s.validate().is_ok());
    }

    #[test]
    fn heterogeneous_autoscale_is_infeasible() {
        let mut spec = tiny_spec();
        spec.autoscale_presets = vec![AutoscalePreset {
            label: "as".into(),
            slo_factor: 3,
            rho_target: 0.7,
            target_attainment: 0.99,
            scale_down_patience: 2,
            min_devices: 1,
            max_devices: 2,
        }];
        let cache = DesignCache::disabled();
        let archive = RefCell::new(BTreeMap::new());
        let problem = FleetProblem {
            spec: &spec,
            cache: &cache,
            archive: &archive,
            reference: PlanObjectives { device_seconds: 1.0, p99_ms: 1.0, energy_j: 1.0 },
            weights: [1.0, 1.0, 1.0],
        };
        // counts [1,1] + preset 0 → infeasible; homogeneous [0,1] +
        // preset 0 → feasible.
        assert_eq!(problem.objectives_for(&[1, 1, 0, 0, 0, 1]), None);
        assert!(problem.objectives_for(&[0, 1, 0, 0, 0, 1]).is_some());
        assert!(problem.fitness(&[1, 1, 0, 0, 0, 1]) <= INFEASIBLE);
    }

    #[test]
    fn dominance_is_strict() {
        let a = PlanObjectives { device_seconds: 1.0, p99_ms: 2.0, energy_j: 3.0 };
        let b = PlanObjectives { device_seconds: 1.0, p99_ms: 2.5, energy_j: 3.0 };
        assert!(a.dominates(&b));
        assert!(!b.dominates(&a));
        assert!(!a.dominates(&a), "equal points never dominate each other");
    }

    #[test]
    fn canonical_zeroes_unused_variant_genes() {
        let spec = tiny_spec();
        // Template 0 unused → its variant gene is a don't-care.
        assert_eq!(spec.canonical(&[0, 1, 0, 0, 0, 0]), vec![0, 1, 0, 0, 0, 0]);
        assert_eq!(spec.genes(), 6);
    }
}
