//! Binary search over a monotone scale parameter (Algorithm 1, line 11:
//! "use binary search for the lowest resource usage on MoE depending on
//! the upper bound latency L_MSA").

/// Find the smallest `x` in `lo..=hi` with `pred(x)` true, assuming
/// `pred` is monotone (false…false true…true). Returns None if no `x`
/// satisfies it.
pub fn min_satisfying<F: FnMut(usize) -> bool>(
    lo: usize,
    hi: usize,
    mut pred: F,
) -> Option<usize> {
    if lo > hi {
        return None;
    }
    let (mut lo, mut hi) = (lo, hi);
    if !pred(hi) {
        return None;
    }
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if pred(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    Some(lo)
}

/// Find the largest `x` in `lo..=hi` with `pred(x)` true, assuming
/// monotone true…true false…false.
pub fn max_satisfying<F: FnMut(usize) -> bool>(
    lo: usize,
    hi: usize,
    mut pred: F,
) -> Option<usize> {
    if lo > hi || !pred(lo) {
        return None;
    }
    let (mut lo, mut hi) = (lo, hi);
    while lo < hi {
        let mid = lo + (hi - lo).div_ceil(2);
        if pred(mid) {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    Some(lo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, prop_assert};

    #[test]
    fn min_satisfying_finds_threshold() {
        assert_eq!(min_satisfying(0, 100, |x| x >= 37), Some(37));
        assert_eq!(min_satisfying(0, 100, |_x| true), Some(0));
        assert_eq!(min_satisfying(0, 100, |_| false), None);
        assert_eq!(min_satisfying(5, 4, |_| true), None);
    }

    #[test]
    fn max_satisfying_finds_threshold() {
        assert_eq!(max_satisfying(0, 100, |x| x <= 42), Some(42));
        assert_eq!(max_satisfying(0, 100, |_| true), Some(100));
        assert_eq!(max_satisfying(0, 100, |_| false), None);
    }

    #[test]
    fn counts_evaluations_logarithmically() {
        let mut evals = 0;
        min_satisfying(0, 1 << 20, |x| {
            evals += 1;
            x >= 123_456
        });
        assert!(evals <= 22, "evals {evals}");
    }

    #[test]
    fn prop_agrees_with_linear_scan() {
        check(200, |g| {
            let hi = g.usize(0, 200);
            let t = g.usize(0, hi.max(1) + 20); // threshold possibly out of range
            let fast = min_satisfying(0, hi, |x| x >= t);
            let slow = (0..=hi).find(|&x| x >= t);
            prop_assert(fast == slow, format!("hi={hi} t={t}: {fast:?} vs {slow:?}"))
        });
    }
}
