//! Memoized evaluation engine for the 2-stage HAS (the GA fitness hot
//! path).
//!
//! The genome `[T_a, N_a, T_in, T_out, N_L]` factors:
//!
//! * `L_MoE` (block-2 latency) depends only on the three linear genes —
//!   |T_in|·|T_out|·|N_L| distinct values, shared by the stage-1 scan,
//!   every per-`num` GA, and the stage-2 binary search;
//! * `L_MSA` depends only on `(num, T_a, N_a)` — |num|·|T_a|·|N_a|
//!   values;
//! * the resource check is the only part that needs the full genome.
//!
//! [`EvalTables`] precomputes both latency tables once per (model,
//! memory fabric); they are budget-independent, so a platform-derate
//! sweep reuses them across searches. [`MemoFcGa`] layers a
//! genome-keyed fitness memo on top so duplicate genomes (elites,
//! converged offspring) cost a hash lookup. Every value returned is
//! bit-identical to the seed's direct evaluation — the property test
//! in `has/mod.rs` enforces this against a retained naive evaluator.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use crate::has::ga::GaProblem;
use crate::has::space::Space;
use crate::has::{block2_cycles, linear_candidates};
use crate::models::ModelConfig;
use crate::resources::{LinearParams, Resources};
use crate::sim::engine::msa_block_cycles_model;
use crate::sim::memory::{BwAllocation, MemorySystem};
use crate::sim::HwChoice;

/// Precomputed latency/resource tables for one (model, fabric, space).
pub struct EvalTables {
    pub model: ModelConfig,
    pub space: Space,
    pub mem: MemorySystem,
    pub bw: BwAllocation,
    /// Fabric identity (mem_channels, bw_gbs, freq_mhz) the tables were
    /// built for. Budgets (derates) may vary per search; the fabric may
    /// not.
    pub fabric: (usize, f64, f64),
    /// L_MoE per linear-gene combo, flat over (t_in, t_out, n_l) idx.
    l_moe: Vec<f64>,
    /// L_MSA per (num, t_a, n_a) idx.
    l_msa: Vec<f64>,
    /// Resources of {minimal MSA + lin} per linear combo — the seed's
    /// `feasible_with` check reduced to a precomputed `fits(budget)`.
    min_msa_res: Vec<Resources>,
    /// Linear configs in the seed's DSP-sorted candidate order, each
    /// with its flat linear index.
    pub candidates: Vec<(LinearParams, usize)>,
}

impl EvalTables {
    /// Build all tables eagerly: |lin| block-2 evaluations plus
    /// |num|·|T_a|·|N_a| MSA evaluations — a few hundred cheap model
    /// calls, after which every GA fitness is two array lookups plus
    /// the resource check.
    pub fn build(
        model: &ModelConfig,
        space: &Space,
        mem: MemorySystem,
        bw: BwAllocation,
        fabric: (usize, f64, f64),
    ) -> EvalTables {
        crate::util::counters::count_table_build();
        // The genome memo packs one byte per gene (MemoFcGa::key);
        // keep that exact by construction.
        for gene in 0..Space::GENES {
            assert!(
                space.gene_len(gene) <= 256,
                "gene {gene} has {} candidates; the genome memo packs 8 bits per gene",
                space.gene_len(gene)
            );
        }
        let n_lin = space.t_in.len() * space.t_out.len() * space.n_l.len();
        let mut l_moe = vec![0.0; n_lin];
        let mut min_msa_res = vec![Resources::default(); n_lin];
        let min_msa = HwChoice::minimal(space.q_bits, space.a_bits);
        for (i2, &t_in) in space.t_in.iter().enumerate() {
            for (i3, &t_out) in space.t_out.iter().enumerate() {
                for (i4, &n_l) in space.n_l.iter().enumerate() {
                    let lin = LinearParams { t_in, t_out, n_l };
                    let li = lin_index(space, i2, i3, i4);
                    l_moe[li] = block2_cycles(model, &lin, &mem, bw.moe_weights);
                    min_msa_res[li] = HwChoice { lin, ..min_msa }.resources(
                        model.heads,
                        model.patches,
                        model.dim,
                    );
                }
            }
        }

        let n_msa = space.num.len() * space.t_a.len() * space.n_a.len();
        let mut l_msa = vec![0.0; n_msa];
        for (i_num, &num) in space.num.iter().enumerate() {
            for i0 in 0..space.t_a.len() {
                for i1 in 0..space.n_a.len() {
                    // The MSA model reads only (num, T_a, N_a, q_bits);
                    // linear genes are don't-care here.
                    let hw = space.decode(num, &[i0, i1, 0, 0, 0]);
                    l_msa[msa_index(space, i_num, i0, i1)] =
                        msa_block_cycles_model(model, &hw, &mem, bw.msa);
                }
            }
        }

        // Same enumeration + stable sort as the seed's candidate list,
        // with flat indices attached.
        let sorted = linear_candidates(space);
        let candidates = sorted
            .into_iter()
            .map(|lin| {
                let i2 = space.t_in.iter().position(|&v| v == lin.t_in).expect("t_in in space");
                let i3 =
                    space.t_out.iter().position(|&v| v == lin.t_out).expect("t_out in space");
                let i4 = space.n_l.iter().position(|&v| v == lin.n_l).expect("n_l in space");
                (lin, lin_index(space, i2, i3, i4))
            })
            .collect();

        EvalTables {
            model: model.clone(),
            space: space.clone(),
            mem,
            bw,
            fabric,
            l_moe,
            l_msa,
            min_msa_res,
            candidates,
        }
    }

    #[inline]
    pub fn lin_index_of(&self, genome: &[usize]) -> usize {
        lin_index(&self.space, genome[2], genome[3], genome[4])
    }

    #[inline]
    pub fn l_moe_at(&self, lin_idx: usize) -> f64 {
        self.l_moe[lin_idx]
    }

    #[inline]
    pub fn l_moe_of(&self, genome: &[usize]) -> f64 {
        self.l_moe[self.lin_index_of(genome)]
    }

    #[inline]
    pub fn l_msa_of(&self, num_idx: usize, genome: &[usize]) -> f64 {
        self.l_msa[msa_index(&self.space, num_idx, genome[0], genome[1])]
    }

    #[inline]
    pub fn min_msa_res_at(&self, lin_idx: usize) -> &Resources {
        &self.min_msa_res[lin_idx]
    }

    /// Stage-1 target (Algorithm 1 line 3): best L_MoE over every
    /// linear config that fits the budget next to a minimal MSA —
    /// now a filtered scan over the precomputed table.
    pub fn l_moe_target(&self, budget: &Resources) -> f64 {
        let mut best = f64::INFINITY;
        for &(_, li) in &self.candidates {
            if !self.min_msa_res[li].fits(budget) {
                continue;
            }
            let l = self.l_moe[li];
            if l < best {
                best = l;
            }
        }
        best
    }
}

#[inline]
fn lin_index(space: &Space, i2: usize, i3: usize, i4: usize) -> usize {
    (i2 * space.t_out.len() + i3) * space.n_l.len() + i4
}

#[inline]
fn msa_index(space: &Space, num_idx: usize, i0: usize, i1: usize) -> usize {
    (num_idx * space.t_a.len() + i0) * space.n_a.len() + i1
}

/// Table-backed GA problem for one `num`, with a genome-keyed fitness
/// memo (duplicate genomes — elites, converged offspring — cost a hash
/// lookup instead of a model evaluation).
pub struct MemoFcGa<'a> {
    pub tables: &'a EvalTables,
    pub num_idx: usize,
    pub budget: Resources,
    pub l_moe_target: f64,
    memo: RefCell<HashMap<u64, f64>>,
    true_evals: Cell<usize>,
    cache_hits: Cell<usize>,
}

impl<'a> MemoFcGa<'a> {
    pub fn new(
        tables: &'a EvalTables,
        num_idx: usize,
        budget: Resources,
        l_moe_target: f64,
    ) -> MemoFcGa<'a> {
        MemoFcGa {
            tables,
            num_idx,
            budget,
            l_moe_target,
            memo: RefCell::new(HashMap::new()),
            true_evals: Cell::new(0),
            cache_hits: Cell::new(0),
        }
    }

    /// Fitness calls that actually evaluated (memo misses).
    pub fn true_evals(&self) -> usize {
        self.true_evals.get()
    }

    /// Fitness calls served from the memo.
    pub fn cache_hits(&self) -> usize {
        self.cache_hits.get()
    }

    #[inline]
    fn key(genome: &[usize]) -> u64 {
        // Gene cardinalities are < 256, so 8 bits per gene is exact.
        genome.iter().fold(0u64, |k, &g| (k << 8) | g as u64)
    }

    /// The seed's `FcGa::eval`, backed by the tables: full decode,
    /// resource check, and the two latency lookups.
    pub fn eval(&self, genome: &[usize]) -> (HwChoice, f64, f64, bool) {
        let t = self.tables;
        let hw = t.space.decode(
            t.space.num[self.num_idx],
            &[genome[0], genome[1], genome[2], genome[3], genome[4]],
        );
        let res = hw.resources(t.model.heads, t.model.patches, t.model.dim);
        if !res.fits(&self.budget) {
            return (hw, f64::INFINITY, f64::INFINITY, false);
        }
        (hw, t.l_msa_of(self.num_idx, genome), t.l_moe_of(genome), true)
    }

    fn fitness_uncached(&self, genome: &[usize]) -> f64 {
        let t = self.tables;
        let hw = t.space.decode(
            t.space.num[self.num_idx],
            &[genome[0], genome[1], genome[2], genome[3], genome[4]],
        );
        let res = hw.resources(t.model.heads, t.model.patches, t.model.dim);
        if !res.fits(&self.budget) {
            return -res.max_util(&self.budget);
        }
        // target/bound, ≥ 1 exactly when the MSA block keeps up with
        // the best achievable MoE latency (the paper's fit score).
        self.l_moe_target / t.l_msa_of(self.num_idx, genome).max(t.l_moe_of(genome))
    }
}

impl GaProblem for MemoFcGa<'_> {
    fn genes(&self) -> usize {
        Space::GENES
    }

    fn gene_len(&self, gene: usize) -> usize {
        self.tables.space.gene_len(gene)
    }

    fn fitness(&self, genome: &[usize]) -> f64 {
        let key = Self::key(genome);
        if let Some(&f) = self.memo.borrow().get(&key) {
            self.cache_hits.set(self.cache_hits.get() + 1);
            return f;
        }
        let f = self.fitness_uncached(genome);
        self.memo.borrow_mut().insert(key, f);
        self.true_evals.set(self.true_evals.get() + 1);
        // Process-wide tally backing the design cache's "warm run does
        // zero GA work" assertion (memo hits are free, not counted).
        crate::util::counters::count_ga_true_eval();
        f
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::m3vit_small;
    use crate::resources::Platform;

    fn tables_for(platform: &Platform) -> EvalTables {
        let model = m3vit_small();
        let space = Space::paper(16, 32);
        let mem = MemorySystem::new(platform.mem_channels, platform.bw_gbs, platform.freq_mhz);
        let bw = BwAllocation::for_channels(platform.mem_channels);
        EvalTables::build(
            &model,
            &space,
            mem,
            bw,
            (platform.mem_channels, platform.bw_gbs, platform.freq_mhz),
        )
    }

    #[test]
    fn tables_match_direct_evaluation() {
        let plat = Platform::zcu102();
        let t = tables_for(&plat);
        let mut rng = crate::util::rng::Rng::new(42);
        for _ in 0..200 {
            let g = t.space.random_genome(&mut rng);
            let genome = [g[0], g[1], g[2], g[3], g[4]];
            // Direct (seed-style) recomputation.
            let hw = t.space.decode(t.space.num[0], &genome);
            let want_moe = block2_cycles(&t.model, &hw.lin, &t.mem, t.bw.moe_weights);
            let want_msa = msa_block_cycles_model(&t.model, &hw, &t.mem, t.bw.msa);
            assert_eq!(t.l_moe_of(&genome), want_moe, "L_MoE table mismatch at {genome:?}");
            assert_eq!(t.l_msa_of(0, &genome), want_msa, "L_MSA table mismatch at {genome:?}");
        }
    }

    #[test]
    fn stage1_target_matches_seed_scan() {
        let plat = Platform::zcu102();
        let t = tables_for(&plat);
        let budget = plat.budget();
        // Seed-style scan: sorted candidates, feasible with minimal
        // MSA, min of direct block-2 evaluations.
        let min_msa = HwChoice::minimal(t.space.q_bits, t.space.a_bits);
        let mut want = f64::INFINITY;
        for lin in linear_candidates(&t.space) {
            let hw = HwChoice { lin, ..min_msa };
            if !hw.resources(t.model.heads, t.model.patches, t.model.dim).fits(&budget) {
                continue;
            }
            let l = block2_cycles(&t.model, &lin, &t.mem, t.bw.moe_weights);
            if l < want {
                want = l;
            }
        }
        assert_eq!(t.l_moe_target(&budget), want);
    }

    #[test]
    fn memo_counts_hits_and_misses() {
        let plat = Platform::zcu102();
        let t = tables_for(&plat);
        let p = MemoFcGa::new(&t, 1, plat.budget(), 1e6);
        let a = p.fitness(&[1, 2, 3, 4, 5]);
        let b = p.fitness(&[1, 2, 3, 4, 5]);
        let c = p.fitness(&[0, 2, 3, 4, 5]);
        assert_eq!(a, b);
        assert_ne!(MemoFcGa::key(&[1, 2, 3, 4, 5]), MemoFcGa::key(&[0, 2, 3, 4, 5]));
        assert_eq!(p.true_evals(), 2);
        assert_eq!(p.cache_hits(), 1);
        let _ = c;
    }

    #[test]
    fn candidates_cover_every_lin_combo_once() {
        let t = tables_for(&Platform::zcu102());
        let n = t.space.t_in.len() * t.space.t_out.len() * t.space.n_l.len();
        assert_eq!(t.candidates.len(), n);
        let mut seen = vec![false; n];
        for &(_, li) in &t.candidates {
            assert!(!seen[li], "duplicate linear index {li}");
            seen[li] = true;
        }
        // Sorted by DSP-footprint (tile product), ties by N_L — the
        // monotone axis stage 2 binary-searches along.
        for w in t.candidates.windows(2) {
            let a = w[0].0.t_in * w[0].0.t_out * w[0].0.n_l;
            let b = w[1].0.t_in * w[1].0.t_out * w[1].0.n_l;
            assert!(a <= b, "candidates not DSP-sorted");
        }
    }
}
