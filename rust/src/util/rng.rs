//! Deterministic PRNG (SplitMix64 + xoshiro256**).
//!
//! The vendored crate set has no `rand`; the GA (has/ga.rs), the
//! property-test harness (util/proptest.rs) and the synthetic workload
//! generators all draw from this. Everything is seedable so searches,
//! tests and benches are reproducible run-to-run.

/// SplitMix64 — used to seed xoshiro and for cheap one-off streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Uniform integer in [0, n). Lemire-style rejection-free reduction
    /// is unnecessary here — modulo bias is irrelevant for GA mutation —
    /// but use widening multiply anyway: it is branch-free and unbiased
    /// enough for our ranges (n << 2^32).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Standard normal via Box–Muller (used by synthetic workloads).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit: {seen:?}");
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(5);
        let (mut lo_hit, mut hi_hit) = (false, false);
        for _ in 0..2_000 {
            let x = r.range(3, 9);
            assert!((3..=9).contains(&x));
            lo_hit |= x == 3;
            hi_hit |= x == 9;
        }
        assert!(lo_hit && hi_hit);
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(6);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
