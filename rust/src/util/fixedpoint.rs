//! Fixed-point quantization helpers for the bit-width studies.
//!
//! The paper evaluates W16A32 (Table II) and INT16 (Table III), and its
//! resource model hinges on the bit-width function Ψ(q) (Eq. 2). This
//! module provides symmetric per-tensor quantization so examples/tests
//! can measure the numeric error the sim's bit-width knob corresponds
//! to (examples/bitwidth_study.rs).

/// Symmetric linear quantizer to `bits`-wide signed integers.
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    pub bits: u32,
    pub scale: f32,
}

impl Quantizer {
    /// Calibrate scale from the max-abs of `data`.
    pub fn calibrate(bits: u32, data: &[f32]) -> Self {
        assert!((2..=32).contains(&bits), "bits {bits}");
        let max_abs = data.iter().fold(0f32, |m, x| m.max(x.abs()));
        let qmax = ((1i64 << (bits - 1)) - 1) as f32;
        let scale = if max_abs > 0.0 { max_abs / qmax } else { 1.0 };
        Self { bits, scale }
    }

    #[inline]
    pub fn quantize(&self, x: f32) -> i32 {
        let qmax = ((1i64 << (self.bits - 1)) - 1) as i32;
        let qmin = -qmax - 1;
        (x / self.scale).round().clamp(qmin as f32, qmax as f32) as i32
    }

    #[inline]
    pub fn dequantize(&self, q: i32) -> f32 {
        q as f32 * self.scale
    }

    /// Quantize-dequantize round trip (fake quantization).
    pub fn fake_quant(&self, data: &[f32]) -> Vec<f32> {
        data.iter().map(|&x| self.dequantize(self.quantize(x))).collect()
    }

    /// RMS error introduced by quantizing `data`.
    pub fn rms_error(&self, data: &[f32]) -> f64 {
        if data.is_empty() {
            return 0.0;
        }
        let se: f64 = data
            .iter()
            .map(|&x| {
                let e = (x - self.dequantize(self.quantize(x))) as f64;
                e * e
            })
            .sum();
        (se / data.len() as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_exact_for_grid_values() {
        let q = Quantizer { bits: 8, scale: 0.5 };
        for i in -128..=127 {
            let x = i as f32 * 0.5;
            assert_eq!(q.quantize(x), i);
            assert_eq!(q.dequantize(q.quantize(x)), x);
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let q = Quantizer { bits: 8, scale: 1.0 };
        assert_eq!(q.quantize(1e9), 127);
        assert_eq!(q.quantize(-1e9), -128);
    }

    #[test]
    fn calibrated_error_bounded_by_half_lsb() {
        let mut r = Rng::new(11);
        let data: Vec<f32> = (0..1000).map(|_| r.f32_range(-3.0, 3.0)).collect();
        let q = Quantizer::calibrate(16, &data);
        for &x in &data {
            let e = (x - q.dequantize(q.quantize(x))).abs();
            // 0.51: f32 rounding can sit exactly on the half-LSB edge.
            assert!(e <= 0.51 * q.scale, "err {e} scale {}", q.scale);
        }
    }

    #[test]
    fn more_bits_less_error() {
        let mut r = Rng::new(12);
        let data: Vec<f32> = (0..2000).map(|_| r.f32_range(-1.0, 1.0)).collect();
        let e8 = Quantizer::calibrate(8, &data).rms_error(&data);
        let e16 = Quantizer::calibrate(16, &data).rms_error(&data);
        assert!(e16 < e8 / 100.0, "e8={e8} e16={e16}");
    }
}
