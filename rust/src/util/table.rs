//! Plain-text table rendering for the paper-table benches (report/).

/// A simple column-aligned table with a header row.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    pub fn render(&self) -> String {
        let w = self.widths();
        let sep: String = w
            .iter()
            .map(|n| "-".repeat(n + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// CSV dump (figure-series consumers / EXPERIMENTS.md appendices).
    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format helpers shared by report/.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}
pub fn i0(x: f64) -> String {
    format!("{}", x.round() as i64)
}
pub fn kfmt(x: f64) -> String {
    format!("{:.1}K", x / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row_str(&["1", "2"]).row_str(&["333", "4"]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("333"));
        let lines: Vec<&str> = r.lines().collect();
        // header + sep + 2 rows + title
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("", &["x", "y"]);
        t.row_str(&["1", "2"]);
        assert_eq!(t.to_csv(), "x,y\n1,2\n");
    }
}
