//! Re-export shim: the work counters moved to
//! [`crate::obs::registry`] (ISSUE 7 folded them into the
//! observability layer so CLI surfaces and benches share one snapshot
//! type). Existing `util::counters::*` call sites keep working; new
//! code should use `obs::registry` directly.

pub use crate::obs::registry::*;
