//! Shared infrastructure: deterministic RNG, property-test harness,
//! bench harness, table rendering, fixed-point quantization.

pub mod bench;
pub mod fixedpoint;
pub mod proptest;
pub mod rng;
pub mod table;
