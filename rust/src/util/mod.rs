//! Shared infrastructure: deterministic RNG, property-test harness,
//! bench harness, table rendering, fixed-point quantization, injectable
//! clocks (wall / virtual).

pub mod bench;
pub mod clock;
pub mod counters;
pub mod fixedpoint;
pub mod proptest;
pub mod rng;
pub mod table;
