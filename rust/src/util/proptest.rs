//! Minimal property-testing harness (the vendored crate set has no
//! proptest). Generates seeded random cases, runs the property, and on
//! failure performs a simple halving shrink over integer parameters,
//! reporting the smallest failing case it found.
//!
//! Usage:
//! ```ignore
//! check(200, |g| {
//!     let n = g.usize(1, 64);
//!     let xs = g.vec_f32(n, -1.0, 1.0);
//!     prop_assert(invariant(&xs), format!("failed for {xs:?}"));
//! });
//! ```

use crate::util::rng::Rng;

/// Case generator handed to the property closure.
pub struct Gen {
    rng: Rng,
    /// Log of drawn integers (for shrink reporting).
    pub draws: Vec<(String, u64)>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), draws: Vec::new() }
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range(lo, hi);
        self.draws.push((format!("usize[{lo},{hi}]"), v as u64));
        v
    }

    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.draws.push(("u64".into(), v));
        v
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.f64()
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.f32_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.below(xs.len());
        self.draws.push(("pick".into(), i as u64));
        &xs[i]
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.rng.range(lo, hi)).collect()
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.f32_range(lo, hi)).collect()
    }
}

/// Outcome of one property execution.
pub type PropResult = Result<(), String>;

/// Assert inside a property.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` seeded cases of `prop`. Panics with the first failing
/// seed and message; the failing seed is stable so it can be replayed
/// by calling `run_case(seed, prop)`.
pub fn check<F>(cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> PropResult,
{
    // Base seed is fixed: identical CI behaviour run-to-run. Override
    // with UBIMOE_PROPTEST_SEED for exploratory fuzzing.
    let base = std::env::var("UBIMOE_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xDEAD_BEEFu64);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property failed (case {i}, seed {seed:#x}):\n  {msg}\n  draws: {:?}",
                g.draws
            );
        }
    }
}

/// Replay a single case by seed (debugging helper).
pub fn run_case<F>(seed: u64, prop: F) -> PropResult
where
    F: Fn(&mut Gen) -> PropResult,
{
    prop(&mut Gen::new(seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(50, |g| {
            let n = g.usize(1, 100);
            prop_assert(n >= 1 && n <= 100, "bounds")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(50, |g| {
            let n = g.usize(0, 10);
            prop_assert(n < 10, format!("n={n}"))
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let prop = |g: &mut Gen| {
            let a = g.u64();
            let b = g.u64();
            prop_assert(a != b || a == b, "trivial")
        };
        assert!(run_case(42, prop).is_ok());
        // Same seed, same draws.
        let mut g1 = Gen::new(99);
        let mut g2 = Gen::new(99);
        assert_eq!(g1.u64(), g2.u64());
    }
}
