//! Micro-benchmark harness (criterion is not in the vendored crate set).
//!
//! Reports median and MAD over timed iterations after a warmup, plus
//! throughput if the caller supplies an items-per-iteration count. All
//! `benches/*.rs` targets are `harness = false` binaries built on this.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub median: Duration,
    pub mad: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: usize,
}

impl Measurement {
    pub fn per_sec(&self, items: f64) -> f64 {
        items / self.median.as_secs_f64()
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} median {:>12?}  mad {:>10?}  (n={}, min {:?}, max {:?})",
            self.name, self.median, self.mad, self.iters, self.min, self.max
        )
    }
}

/// Time `f` with warmup. Chooses iteration count so total runtime stays
/// near `budget` (default 2s via [`bench`]).
pub fn bench_with<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Measurement {
    // Warmup + calibration: run until 10% of budget or 3 iterations.
    let warm_start = Instant::now();
    let mut warm_iters = 0usize;
    while warm_iters < 3 || warm_start.elapsed() < budget / 10 {
        f();
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    let per_iter = warm_start.elapsed() / warm_iters as u32;
    let iters = ((budget.as_secs_f64() / per_iter.as_secs_f64().max(1e-9)) as usize)
        .clamp(5, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let median = samples[samples.len() / 2];
    let mut devs: Vec<Duration> = samples
        .iter()
        .map(|s| if *s > median { *s - median } else { median - *s })
        .collect();
    devs.sort_unstable();
    Measurement {
        name: name.to_string(),
        median,
        mad: devs[devs.len() / 2],
        min: samples[0],
        max: *samples.last().unwrap(),
        iters,
    }
}

/// 2-second-budget benchmark; prints the measurement and returns it.
pub fn bench<F: FnMut()>(name: &str, f: F) -> Measurement {
    let m = bench_with(name, Duration::from_secs(2), f);
    println!("{m}");
    m
}

/// Quick variant for cheap functions inside sweeps (200 ms budget).
pub fn bench_quick<F: FnMut()>(name: &str, f: F) -> Measurement {
    let m = bench_with(name, Duration::from_millis(200), f);
    println!("{m}");
    m
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let m = bench_with("spin", Duration::from_millis(50), || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(m.iters >= 5);
        assert!(m.median > Duration::ZERO);
        assert!(m.min <= m.median && m.median <= m.max);
    }

    #[test]
    fn per_sec_throughput() {
        let m = Measurement {
            name: "x".into(),
            median: Duration::from_millis(10),
            mad: Duration::ZERO,
            min: Duration::from_millis(9),
            max: Duration::from_millis(11),
            iters: 10,
        };
        let tput = m.per_sec(100.0);
        assert!((tput - 10_000.0).abs() < 1e-6);
    }
}
