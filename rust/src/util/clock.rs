//! Injectable time source.
//!
//! The batcher (coordinator/batcher.rs) and the fleet-serving DES
//! (serve/) both need "now", but with different physics: the runtime
//! path wants the wall clock, the discrete-event simulator advances a
//! virtual clock by whole events, and tests want time they control
//! (no sleeps, no flaky `Instant` arithmetic). All three implement
//! [`Clock`]: a monotone `now()` expressed as a [`Duration`] since the
//! clock's own epoch — durations subtract/compare exactly, and a
//! virtual clock is just a settable counter.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A monotone time source. `now()` is the elapsed time since the
/// clock's epoch; only differences between `now()` values are ever
/// meaningful, so the epoch itself is private to the implementation.
pub trait Clock {
    fn now(&self) -> Duration;
}

/// Real time: epoch is the moment of construction.
#[derive(Clone, Debug)]
pub struct WallClock {
    epoch: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock { epoch: Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }
}

/// Simulated time, advanced explicitly by its owner (the DES event
/// loop, or a test). Clones share the same underlying counter, so the
/// event loop can hold one handle and hand another to a `Batcher` —
/// every `now()` the batcher reads is the event currently being
/// processed. Backed by an atomic nanosecond counter (not `Rc<Cell>`)
/// so the clock — and anything holding a `Box<dyn Clock + Send>` —
/// stays `Send`; Duration values are integer nanoseconds, so the
/// round-trip is exact.
#[derive(Clone, Debug, Default)]
pub struct VirtualClock {
    t: Arc<AtomicU64>,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Jump to an absolute time ≥ the current one (events are
    /// processed in order; going backwards is a bug in the caller).
    pub fn advance_to(&self, t: Duration) {
        let ns = t.as_nanos() as u64;
        let cur = self.t.load(Ordering::SeqCst);
        assert!(
            ns >= cur,
            "virtual clock must be monotone: {t:?} < {:?}",
            Duration::from_nanos(cur)
        );
        self.t.store(ns, Ordering::SeqCst);
    }

    pub fn advance_by(&self, d: Duration) {
        self.t.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.t.load(Ordering::SeqCst))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotone() {
        let c = WallClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn virtual_clock_clones_share_time() {
        let c = VirtualClock::new();
        let view = c.clone();
        assert_eq!(view.now(), Duration::ZERO);
        c.advance_to(Duration::from_millis(7));
        assert_eq!(view.now(), Duration::from_millis(7));
        view.advance_by(Duration::from_millis(3));
        assert_eq!(c.now(), Duration::from_millis(10));
    }

    #[test]
    #[should_panic(expected = "monotone")]
    fn virtual_clock_rejects_rewind() {
        let c = VirtualClock::new();
        c.advance_to(Duration::from_secs(1));
        c.advance_to(Duration::from_millis(1));
    }

    #[test]
    fn trait_object_usable_and_send() {
        let c: Box<dyn Clock + Send> = Box::new(VirtualClock::new());
        assert_eq!(c.now(), Duration::ZERO);
        let w: Box<dyn Clock + Send> = Box::new(WallClock::new());
        let _ = w.now();
        fn assert_send<T: Send>(_: &T) {}
        assert_send(&c);
    }
}
