//! Windowed time-series sampling for the serving DES.
//!
//! A [`SamplerConfig`] on `ServeConfig` makes the DES schedule a
//! `SampleTick` heap event every `every` of *virtual* time (the same
//! pattern as the autoscaler's `ScaleTick`). At each tick the DES
//! appends one [`SampleRow`] per device plus one fleet row
//! (`device == -1`) to a [`TimeSeries`], then resets its window
//! accumulators — every gauge below is therefore *per window*, not
//! cumulative, which is what makes dips and recoveries visible.
//!
//! Determinism: rows contain only integers (ratios are scaled to
//! parts-per-million before storage), timestamps are virtual ns, and
//! ticks fire on the shared event heap — so the CSV is byte-identical
//! across same-(config, seed) runs, and the sampler's presence does
//! not change the `FleetReport` (the DES compensates its own
//! event-count bookkeeping; proptested).
//!
//! Cadence semantics: the first tick fires at `t = every`; ticks keep
//! firing while the arrival horizon has not passed **or** admitted
//! requests remain unsettled (so a post-horizon drain stays visible),
//! and stop at the first tick after both conditions clear — the file
//! covers `[every, makespan + every)` at worst.

use std::time::Duration;

/// Sampling policy carried on `ServeConfig`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SamplerConfig {
    /// Virtual-time window between samples (must be nonzero).
    pub every: Duration,
    /// SLO used for the windowed attainment gauge; `None` reports
    /// vacuous full attainment.
    pub slo: Option<Duration>,
}

impl SamplerConfig {
    /// `every` sized so a run yields ~`target_rows` fleet rows
    /// (clamped to ≥ 1 ms so tiny horizons don't tick pathologically).
    pub fn for_horizon(horizon: Duration, target_rows: u32) -> SamplerConfig {
        let every = (horizon / target_rows.max(1)).max(Duration::from_millis(1));
        SamplerConfig { every, slo: None }
    }
}

/// Integer-scaled ratio in parts-per-million; 0 when the denominator
/// is 0 (callers wanting vacuous-success semantics special-case the
/// empty window themselves).
pub fn ppm(num: u128, den: u128) -> u64 {
    if den == 0 {
        0
    } else {
        (num.saturating_mul(1_000_000) / den) as u64
    }
}

/// One sampled gauge row. `device == -1` is the fleet aggregate; all
/// rate-like fields are over the window that ended at `t_ns`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SampleRow {
    pub t_ns: u64,
    /// Device index, or `-1` for the fleet row.
    pub device: i64,
    /// Requests waiting in the batcher at the tick instant.
    pub queue: u64,
    /// Requests riding in-flight batches at the tick instant.
    pub in_flight: u64,
    /// Busy time over the window, ppm (device rows); mean over active
    /// devices for the fleet row.
    pub busy_ppm: u64,
    /// Requests completed during the window.
    pub completed: u64,
    /// Dispatcher load signal (queued + in-flight copies).
    pub backlog: u64,
    /// Serving devices at the tick instant (fleet row); 1/0 per device.
    pub active: u64,
    /// Windowed e2e p99 (fleet row; 0 when the window completed
    /// nothing).
    pub p99_ns: u64,
    /// Windowed SLO attainment, ppm (fleet row; 1_000_000 when the
    /// window completed nothing or no SLO was configured).
    pub attain_ppm: u64,
}

/// Collected samples plus CSV rendering.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TimeSeries {
    rows: Vec<SampleRow>,
}

impl TimeSeries {
    pub fn new() -> TimeSeries {
        TimeSeries::default()
    }

    pub fn push(&mut self, row: SampleRow) {
        self.rows.push(row);
    }

    pub fn rows(&self) -> &[SampleRow] {
        &self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as CSV (integer-only cells; byte-deterministic).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "t_ns,device,queue,in_flight,busy_ppm,completed,backlog,active,p99_ns,attain_ppm\n",
        );
        for r in &self.rows {
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{}\n",
                r.t_ns,
                r.device,
                r.queue,
                r.in_flight,
                r.busy_ppm,
                r.completed,
                r.backlog,
                r.active,
                r.p99_ns,
                r.attain_ppm
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_shape_and_ppm_math() {
        let mut ts = TimeSeries::new();
        ts.push(SampleRow {
            t_ns: 1_000_000,
            device: -1,
            queue: 2,
            in_flight: 3,
            busy_ppm: ppm(500, 1000),
            completed: 4,
            backlog: 5,
            active: 2,
            p99_ns: 7_000,
            attain_ppm: 1_000_000,
        });
        let csv = ts.to_csv();
        assert!(csv.starts_with("t_ns,device,"));
        assert!(csv.contains("1000000,-1,2,3,500000,4,5,2,7000,1000000\n"));
        assert_eq!(csv.lines().count(), 2);
        assert_eq!(ppm(0, 0), 0);
        assert_eq!(ppm(1, 3), 333_333);
        assert_eq!(ppm(u64::MAX as u128, u64::MAX as u128), 1_000_000);
    }

    #[test]
    fn cadence_helper_clamps() {
        let c = SamplerConfig::for_horizon(Duration::from_secs(2), 200);
        assert_eq!(c.every, Duration::from_millis(10));
        let tiny = SamplerConfig::for_horizon(Duration::from_micros(10), 200);
        assert_eq!(tiny.every, Duration::from_millis(1));
        assert_eq!(SamplerConfig::for_horizon(Duration::from_secs(1), 0).every,
            Duration::from_secs(1));
    }
}
