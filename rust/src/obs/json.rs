//! Shared serde-free JSON emission (and the matching line reader).
//!
//! Everything this repo prints as JSON — bench rows
//! (`benches/*.rs`), trace records ([`crate::obs::trace`]) and the
//! work-counter snapshot ([`crate::obs::registry`]) — goes through
//! [`JsonObj`], so escaping and number formatting live in exactly one
//! place (the same policy as `has/cache.rs`: hand-rolled, no serde,
//! no dependency). The writer produces *flat* single-line objects with
//! the fields in insertion order, which is what makes trace files
//! byte-deterministic: the serialization is a pure function of the
//! record, with no map iteration order or locale anywhere.
//!
//! The reader half ([`field_u64`] & friends) is the minimal inverse
//! for the analyzer: it extracts one named field from one line written
//! by [`JsonObj`]. It is *not* a general JSON parser — it relies on the
//! writer's flat shape (no nested objects, keys are bare identifiers)
//! and is documented as such. That trade keeps the offline analyzer
//! dependency-free too.

/// Append `s` to `out` with JSON string escaping (quotes, backslash,
/// and control characters; everything else passes through verbatim —
/// Rust strings are already valid UTF-8).
pub fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// Single-line flat JSON object builder. Fields appear in insertion
/// order; keys must be bare identifiers (ASCII, no quotes needed) —
/// enforced by debug assertion, since every call site is our own code.
#[derive(Debug)]
pub struct JsonObj {
    buf: String,
}

impl Default for JsonObj {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonObj {
    pub fn new() -> JsonObj {
        JsonObj { buf: String::from("{") }
    }

    fn key(&mut self, k: &str) {
        debug_assert!(
            k.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'_'),
            "JSON keys must be bare identifiers: {k:?}"
        );
        if self.buf.len() > 1 {
            self.buf.push(',');
        }
        self.buf.push('"');
        self.buf.push_str(k);
        self.buf.push_str("\":");
    }

    pub fn u64(&mut self, k: &str, v: u64) -> &mut Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    pub fn i64(&mut self, k: &str, v: i64) -> &mut Self {
        self.key(k);
        self.buf.push_str(&v.to_string());
        self
    }

    /// Fixed-point float field: `{:.decimals$}` formatting, which is
    /// deterministic and locale-independent. Bench rows use this; the
    /// trace itself is integer-only by design.
    pub fn f64(&mut self, k: &str, v: f64, decimals: usize) -> &mut Self {
        self.key(k);
        self.buf.push_str(&format!("{v:.decimals$}"));
        self
    }

    pub fn str(&mut self, k: &str, v: &str) -> &mut Self {
        self.key(k);
        self.buf.push('"');
        escape_into(&mut self.buf, v);
        self.buf.push('"');
        self
    }

    pub fn arr_u64(&mut self, k: &str, vs: &[u64]) -> &mut Self {
        self.key(k);
        self.buf.push('[');
        for (i, v) in vs.iter().enumerate() {
            if i > 0 {
                self.buf.push(',');
            }
            self.buf.push_str(&v.to_string());
        }
        self.buf.push(']');
        self
    }

    /// Finish the object (no trailing newline — the caller owns line
    /// framing).
    pub fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

fn field_start<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat)?;
    Some(&line[at + pat.len()..])
}

/// Extract an unsigned integer field from a [`JsonObj`]-written line.
pub fn field_u64(line: &str, key: &str) -> Option<u64> {
    let rest = field_start(line, key)?;
    let end = rest.find([',', '}', ']']).unwrap_or(rest.len());
    rest[..end].trim().parse().ok()
}

/// Extract a (possibly negative) integer field.
pub fn field_i64(line: &str, key: &str) -> Option<i64> {
    let rest = field_start(line, key)?;
    let end = rest.find([',', '}', ']']).unwrap_or(rest.len());
    rest[..end].trim().parse().ok()
}

/// Extract a string field. Only valid for values our writer emits
/// un-escaped (record kinds, policy names, reason tags — all
/// `[a-z0-9_-]`); returns the raw slice between the quotes.
pub fn field_str<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let rest = field_start(line, key)?;
    let rest = rest.strip_prefix('"')?;
    let end = rest.find('"')?;
    Some(&rest[..end])
}

/// Extract a `[u64,...]` array field (as written by
/// [`JsonObj::arr_u64`]).
pub fn field_u64_list(line: &str, key: &str) -> Option<Vec<u64>> {
    let rest = field_start(line, key)?;
    let rest = rest.strip_prefix('[')?;
    let end = rest.find(']')?;
    let body = &rest[..end];
    if body.is_empty() {
        return Some(Vec::new());
    }
    body.split(',').map(|t| t.trim().parse().ok()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_flat_objects_in_insertion_order() {
        let mut o = JsonObj::new();
        o.u64("t", 5).str("kind", "done").i64("device", -1).f64("x", 1.5, 3).arr_u64(
            "reqs",
            &[1, 2, 3],
        );
        assert_eq!(
            o.finish(),
            r#"{"t":5,"kind":"done","device":-1,"x":1.500,"reqs":[1,2,3]}"#
        );
        assert_eq!(JsonObj::new().finish(), "{}");
    }

    #[test]
    fn escapes_strings() {
        let mut s = String::new();
        escape_into(&mut s, "a\"b\\c\nd\te\u{1}");
        assert_eq!(s, "a\\\"b\\\\c\\nd\\te\\u0001");
        let mut o = JsonObj::new();
        o.str("name", "q\"x");
        assert_eq!(o.finish(), r#"{"name":"q\"x"}"#);
    }

    #[test]
    fn field_extractors_roundtrip() {
        let mut o = JsonObj::new();
        o.u64("t", 42)
            .str("kind", "batch_done")
            .u64("done", 7)
            .i64("device", -1)
            .arr_u64("reqs", &[4, 5])
            .arr_u64("empty", &[]);
        let line = o.finish();
        assert_eq!(field_u64(&line, "t"), Some(42));
        assert_eq!(field_str(&line, "kind"), Some("batch_done"));
        assert_eq!(field_i64(&line, "device"), Some(-1));
        assert_eq!(field_u64_list(&line, "reqs"), Some(vec![4, 5]));
        assert_eq!(field_u64_list(&line, "empty"), Some(vec![]));
        // Key/value collision guard: the value "batch_done" must not
        // satisfy a lookup for key "done".
        assert_eq!(field_u64(&line, "done"), Some(7));
        assert_eq!(field_u64(&line, "missing"), None);
    }
}
