//! Offline trace analysis: span reconstruction, latency breakdown,
//! and ASCII timelines (`ubimoe trace analyze <file>`).
//!
//! Input is a JSONL trace written by [`crate::obs::trace::JsonlSink`].
//! The analyzer replays the records into per-request [`Span`]s —
//! arrival → every dispatched copy → completion or drop — and derives:
//!
//! - a **latency breakdown** (queue wait / service / padding share /
//!   retry backoff / failover penalty, p50/p99/mean each) whose
//!   per-request components reconcile with the run's `FleetReport`:
//!   queue + service + backoff + penalty == e2e for every completed
//!   request (penalty is the residual — time a copy spent on attempts
//!   that lost to a failure, timeout, or hedge);
//! - a **per-device utilization timeline** from batch-execution spans
//!   (`batch_open`/`seu_rerun`, clipped at device failures);
//! - an **incident timeline** aligning fault spans with windowed SLO
//!   attainment, autoscaler actions, and drops.
//!
//! Everything here is pure string → struct → string; the analyzer
//! never touches the simulator, so it works on traces from any run
//! (or any future producer that speaks the schema).

use std::time::Duration;

use crate::obs::json::{field_i64, field_str, field_u64, field_u64_list};
use crate::util::table::Table;

/// Terminal state of one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Still open at end of trace (truncated file or a bug upstream).
    Unresolved,
    Done { device: u64, e2e_ns: u64, queue_ns: u64, service_ns: u64, hedge_won: bool },
    Dropped { attempts: u64 },
    /// Shed at the admission edge (overload protection) — the request
    /// never entered dispatch.
    Rejected { class: u64 },
}

/// One reconstructed request span.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Span {
    pub req: u64,
    pub arrival_ns: u64,
    /// Copies handed to the dispatcher (≥ 1: arrival + failovers +
    /// retries + hedges + parked flushes).
    pub attempts: u64,
    pub retries: u64,
    pub hedged: bool,
    /// Total retry backoff this request waited through.
    pub backoff_ns: u64,
    /// Padding share of the completing batch
    /// (`service · padding / size`).
    pub pad_ns: u64,
    pub outcome: SpanOutcome,
}

impl Span {
    /// Residual latency not explained by the winning attempt's queue +
    /// service or by retry backoff: time burned on attempts that lost
    /// to a device failure, timeout, or hedge race. 0 for undisturbed
    /// requests.
    pub fn failover_penalty_ns(&self) -> u64 {
        match self.outcome {
            SpanOutcome::Done { e2e_ns, queue_ns, service_ns, .. } => {
                e2e_ns.saturating_sub(queue_ns + service_ns + self.backoff_ns)
            }
            _ => 0,
        }
    }
}

/// Parsed trace: spans plus the run-shape context the timelines need.
#[derive(Clone, Debug, Default)]
pub struct TraceAnalysis {
    pub policy: String,
    pub seed: u64,
    pub horizon_ns: u64,
    /// Devices declared by `meta` (autoscaled runs may use more slots).
    pub meta_devices: u64,
    pub spans: Vec<Span>,
    /// `(device, from_ns, to_ns)` outage windows (unclosed → trace end).
    pub fault_spans: Vec<(u64, u64, u64)>,
    /// `(device, from_ns, to_ns)` batch-execution windows.
    pub busy_spans: Vec<(u64, u64, u64)>,
    pub scale_up_ts: Vec<u64>,
    pub scale_down_ts: Vec<u64>,
    pub drop_ts: Vec<u64>,
    /// From the `summary` record (0 when the trace is truncated).
    pub admitted: u64,
    pub completed: u64,
    pub dropped: u64,
    pub makespan_ns: u64,
    /// Timestamp of the last record.
    pub end_ns: u64,
    /// Admission-rejection instants (overload protection).
    pub reject_ts: Vec<u64>,
    /// Circuit-breaker trip instants.
    pub breaker_trip_ts: Vec<u64>,
    /// Circuit-breaker close instants (successful half-open probes).
    pub breaker_close_ts: Vec<u64>,
    /// `(from_ns, to_ns)` degraded (brownout) windows — an enter with
    /// no matching exit closes at the trace end.
    pub brownout_spans: Vec<(u64, u64)>,
    /// From the `overload_summary` record (0 without overload).
    pub rejected: u64,
    /// `route` records seen (expert-sharded traces; 0 otherwise).
    pub route_count: u64,
    /// Routes that landed on a secondary expert (capacity reroutes).
    pub reroute_count: u64,
    /// Routes with every drawn expert over budget (`expert == -1` —
    /// served degraded).
    pub expert_drop_count: u64,
    /// `xfer` records (non-local expert fetches charged to a request).
    pub xfer_count: u64,
    /// No-replica instants (a copy with no live host for its expert).
    pub no_replica_ts: Vec<u64>,
    /// Rebalancer replica-add instants.
    pub replica_add_ts: Vec<u64>,
    /// Rebalancer replica-drop instants.
    pub replica_drop_ts: Vec<u64>,
    /// From the `shard_summary` record (0 without sharding).
    pub shard_routed: u64,
    /// Non-blank lines skipped because the trace was cut off mid-file
    /// (0 for a clean trace) — see [`TraceAnalysis::truncation`].
    pub skipped_lines: usize,
    /// The parse error that ended analysis early, if any. A malformed
    /// record *after* a valid prefix is treated as truncation: the
    /// prefix is analyzed, the tail is counted into
    /// [`TraceAnalysis::skipped_lines`], and the render warns.
    pub truncation: Option<String>,
}

/// Nearest-rank percentile over a sorted slice (0 when empty).
fn pct(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

fn ms(ns: u64) -> String {
    format!("{:.2}", ns as f64 / 1e6)
}

/// Parse a JSONL trace into a [`TraceAnalysis`].
///
/// A malformed record after at least one valid record is treated as a
/// *truncated trace* (a run killed mid-write), not an error: the valid
/// prefix is analyzed and the damage is reported via
/// [`TraceAnalysis::skipped_lines`] / [`TraceAnalysis::truncation`].
///
/// # Errors
/// A message naming the problem when the very first record is already
/// malformed (missing `kind`/`t`, or a record referencing an unknown
/// request) — that is a garbage input, not a truncated trace.
pub fn analyze(text: &str) -> Result<TraceAnalysis, String> {
    let mut a = TraceAnalysis::default();
    let mut open_faults: Vec<Option<u64>> = Vec::new(); // device → fail time
    let mut open_brownout: Option<u64> = None;
    let mut parsed = 0usize;
    let lines: Vec<&str> = text.lines().collect();
    for (i, &line) in lines.iter().enumerate() {
        let lineno = i + 1;
        if line.trim().is_empty() {
            continue;
        }
        match parse_line(&mut a, &mut open_faults, &mut open_brownout, line, lineno) {
            Ok(()) => parsed += 1,
            Err(e) if parsed == 0 => return Err(e),
            Err(e) => {
                a.skipped_lines =
                    lines[i..].iter().filter(|l| !l.trim().is_empty()).count();
                a.truncation = Some(e);
                break;
            }
        }
    }
    // Close outages still open at end of trace.
    for (d, from) in open_faults.iter().enumerate() {
        if let Some(from) = from {
            a.fault_spans.push((d as u64, *from, a.end_ns));
        }
    }
    if let Some(from) = open_brownout {
        a.brownout_spans.push((from, a.end_ns));
    }
    a.fault_spans.sort_unstable();
    // Clip busy spans that died with their device: a batch opened
    // before a failure never ran past it.
    for span in &mut a.busy_spans {
        for &(fd, from, _) in &a.fault_spans {
            if fd == span.0 && span.1 <= from && from < span.2 {
                span.2 = from;
            }
        }
    }
    Ok(a)
}

/// Replay one JSONL record into the analysis.
fn parse_line(
    a: &mut TraceAnalysis,
    open_faults: &mut Vec<Option<u64>>,
    open_brownout: &mut Option<u64>,
    line: &str,
    lineno: usize,
) -> Result<(), String> {
    let need = |v: Option<u64>, what: &str, lineno: usize| {
        v.ok_or_else(|| format!("line {lineno}: missing field {what}"))
    };
    let kind = field_str(line, "kind")
        .ok_or_else(|| format!("line {lineno}: no \"kind\" field"))?;
    let t = need(field_u64(line, "t"), "t", lineno)?;
    a.end_ns = a.end_ns.max(t);
    let span_of = |spans: &mut Vec<Span>, req: u64| -> Result<usize, String> {
        let idx = req as usize;
        if idx >= spans.len() {
            return Err(format!("line {lineno}: record for unknown req {req}"));
        }
        Ok(idx)
    };
    match kind {
            "meta" => {
                a.meta_devices = field_u64(line, "devices").unwrap_or(0);
                a.horizon_ns = field_u64(line, "horizon_ns").unwrap_or(0);
                a.seed = field_u64(line, "seed").unwrap_or(0);
                a.policy = field_str(line, "policy").unwrap_or("?").to_string();
            }
            "arrival" => {
                let req = need(field_u64(line, "req"), "req", lineno)?;
                if req as usize != a.spans.len() {
                    return Err(format!(
                        "line {lineno}: arrival req {req} out of order (expected {})",
                        a.spans.len()
                    ));
                }
                a.spans.push(Span {
                    req,
                    arrival_ns: t,
                    attempts: 0,
                    retries: 0,
                    hedged: false,
                    backoff_ns: 0,
                    pad_ns: 0,
                    outcome: SpanOutcome::Unresolved,
                });
            }
            "dispatch" => {
                let req = need(field_u64(line, "req"), "req", lineno)?;
                let idx = span_of(&mut a.spans, req)?;
                a.spans[idx].attempts += 1;
                if field_u64(line, "hedge") == Some(1) {
                    a.spans[idx].hedged = true;
                }
            }
            "retry" => {
                let req = need(field_u64(line, "req"), "req", lineno)?;
                let idx = span_of(&mut a.spans, req)?;
                a.spans[idx].retries += 1;
                a.spans[idx].backoff_ns += field_u64(line, "backoff_ns").unwrap_or(0);
            }
            "done" => {
                let req = need(field_u64(line, "req"), "req", lineno)?;
                let idx = span_of(&mut a.spans, req)?;
                a.spans[idx].outcome = SpanOutcome::Done {
                    device: field_u64(line, "device").unwrap_or(0),
                    e2e_ns: need(field_u64(line, "e2e_ns"), "e2e_ns", lineno)?,
                    queue_ns: field_u64(line, "queue_ns").unwrap_or(0),
                    service_ns: field_u64(line, "service_ns").unwrap_or(0),
                    hedge_won: field_u64(line, "hedge") == Some(1),
                };
            }
            "drop" => {
                let req = need(field_u64(line, "req"), "req", lineno)?;
                let idx = span_of(&mut a.spans, req)?;
                a.spans[idx].outcome =
                    SpanOutcome::Dropped { attempts: field_u64(line, "attempts").unwrap_or(0) };
                a.drop_ts.push(t);
            }
            "batch_open" | "seu_rerun" => {
                let device = need(field_u64(line, "device"), "device", lineno)?;
                let service = field_u64(line, "service_ns").unwrap_or(0);
                a.busy_spans.push((device, t, t + service));
            }
            "batch_done" => {
                let size = field_u64(line, "size").unwrap_or(1).max(1);
                let padding = field_u64(line, "padding").unwrap_or(0);
                let service = field_u64(line, "service_ns").unwrap_or(0);
                let share = service * padding / size;
                for req in field_u64_list(line, "done").unwrap_or_default() {
                    let idx = span_of(&mut a.spans, req)?;
                    a.spans[idx].pad_ns = share;
                }
            }
            "device_fail" => {
                let d = need(field_u64(line, "device"), "device", lineno)? as usize;
                if d >= open_faults.len() {
                    open_faults.resize(d + 1, None);
                }
                open_faults[d] = Some(t);
            }
            "device_repair" => {
                let d = need(field_u64(line, "device"), "device", lineno)? as usize;
                if let Some(from) = open_faults.get_mut(d).and_then(|f| f.take()) {
                    a.fault_spans.push((d as u64, from, t));
                }
            }
            "scale_up" => a.scale_up_ts.push(t),
            "scale_down" | "retire" => a.scale_down_ts.push(t),
            "summary" => {
                a.admitted = field_u64(line, "admitted").unwrap_or(0);
                a.completed = field_u64(line, "completed").unwrap_or(0);
                a.dropped = field_u64(line, "dropped").unwrap_or(0);
                a.makespan_ns = field_u64(line, "makespan_ns").unwrap_or(0);
            }
            "reject" => {
                let req = need(field_u64(line, "req"), "req", lineno)?;
                let idx = span_of(&mut a.spans, req)?;
                a.spans[idx].outcome =
                    SpanOutcome::Rejected { class: field_u64(line, "class").unwrap_or(0) };
                a.reject_ts.push(t);
            }
            "breaker_trip" => a.breaker_trip_ts.push(t),
            "breaker_close" => a.breaker_close_ts.push(t),
            "brownout_enter" => {
                if open_brownout.is_none() {
                    *open_brownout = Some(t);
                }
            }
            "brownout_exit" => {
                if let Some(from) = open_brownout.take() {
                    a.brownout_spans.push((from, t));
                }
            }
            "overload_summary" => {
                a.rejected = field_u64(line, "rejected").unwrap_or(0);
            }
            "route" => {
                a.route_count += 1;
                if field_u64(line, "rerouted") == Some(1) {
                    a.reroute_count += 1;
                }
                if field_i64(line, "expert") == Some(-1) {
                    a.expert_drop_count += 1;
                }
            }
            "xfer" => a.xfer_count += 1,
            "no_replica" => a.no_replica_ts.push(t),
            "replica_add" => a.replica_add_ts.push(t),
            "replica_drop" => a.replica_drop_ts.push(t),
            "shard_summary" => {
                a.shard_routed = field_u64(line, "routed").unwrap_or(0);
            }
            // Known-but-stateless kinds (flush, attempt_timeout,
            // breaker_probe, scale_tick, ...) and anything newer than
            // this analyzer.
            _ => {}
    }
    Ok(())
}

impl TraceAnalysis {
    /// Highest device index referenced anywhere (busy or fault spans),
    /// +1 — covers autoscaled slots beyond `meta_devices`.
    pub fn device_count(&self) -> usize {
        let hi = self
            .busy_spans
            .iter()
            .map(|s| s.0)
            .chain(self.fault_spans.iter().map(|s| s.0))
            .max()
            .map_or(0, |d| d + 1);
        hi.max(self.meta_devices) as usize
    }

    fn completed_spans(&self) -> impl Iterator<Item = &Span> {
        self.spans.iter().filter(|s| matches!(s.outcome, SpanOutcome::Done { .. }))
    }

    /// Completed-request count (from spans, not the summary record).
    pub fn completed_count(&self) -> u64 {
        self.completed_spans().count() as u64
    }

    pub fn dropped_count(&self) -> u64 {
        self.spans.iter().filter(|s| matches!(s.outcome, SpanOutcome::Dropped { .. })).count()
            as u64
    }

    /// Requests shed at the admission edge (from spans, not the
    /// `overload_summary` record).
    pub fn rejected_count(&self) -> u64 {
        self.spans.iter().filter(|s| matches!(s.outcome, SpanOutcome::Rejected { .. })).count()
            as u64
    }

    /// Whether the trace shows any overload-protection activity —
    /// gates the extra incident-timeline rows.
    pub fn has_overload_activity(&self) -> bool {
        !self.reject_ts.is_empty()
            || !self.breaker_trip_ts.is_empty()
            || !self.brownout_spans.is_empty()
    }

    /// Whether the trace shows any expert-sharding activity — gates
    /// the shard incident-timeline rows and the header line.
    pub fn has_shard_activity(&self) -> bool {
        self.route_count > 0
            || self.shard_routed > 0
            || !self.no_replica_ts.is_empty()
            || !self.replica_add_ts.is_empty()
            || !self.replica_drop_ts.is_empty()
    }

    /// Total dispatched copies across all spans.
    pub fn total_attempts(&self) -> u64 {
        self.spans.iter().map(|s| s.attempts).sum()
    }

    /// Exact mean end-to-end latency over completed spans, in ns.
    pub fn mean_e2e_ns(&self) -> u64 {
        let (mut sum, mut n) = (0u128, 0u128);
        for s in self.completed_spans() {
            if let SpanOutcome::Done { e2e_ns, .. } = s.outcome {
                sum += e2e_ns as u128;
                n += 1;
            }
        }
        if n == 0 { 0 } else { (sum / n) as u64 }
    }

    /// Latency breakdown over completed spans. Columns: p50 / p99 /
    /// mean (ms) and each component's share of Σ e2e. The components
    /// queue + service + backoff + penalty sum *exactly* to e2e per
    /// request (padding is a sub-part of service, shown for visibility
    /// but excluded from the sum).
    pub fn breakdown_table(&self) -> Table {
        let mut cols: [Vec<u64>; 6] = Default::default();
        for s in self.completed_spans() {
            if let SpanOutcome::Done { e2e_ns, queue_ns, service_ns, .. } = s.outcome {
                cols[0].push(queue_ns);
                cols[1].push(service_ns);
                cols[2].push(s.pad_ns);
                cols[3].push(s.backoff_ns);
                cols[4].push(s.failover_penalty_ns());
                cols[5].push(e2e_ns);
            }
        }
        let total_e2e: u128 = cols[5].iter().map(|&v| v as u128).sum();
        let names =
            ["queue wait", "service", "padding*", "retry backoff", "failover penalty", "e2e"];
        let mut t = Table::new(
            format!("latency breakdown ({} completed requests)", cols[5].len()),
            &["component", "p50 ms", "p99 ms", "mean ms", "share %"],
        );
        for (name, vals) in names.iter().zip(cols.iter_mut()) {
            let sum: u128 = vals.iter().map(|&v| v as u128).sum();
            let mean = if vals.is_empty() { 0 } else { (sum / vals.len() as u128) as u64 };
            vals.sort_unstable();
            let share = if total_e2e == 0 {
                0.0
            } else {
                100.0 * sum as f64 / total_e2e as f64
            };
            t.row(&[
                name.to_string(),
                ms(pct(vals, 50.0)),
                ms(pct(vals, 99.0)),
                ms(mean),
                format!("{share:.1}"),
            ]);
        }
        t
    }

    fn bucket_axis(&self, buckets: usize) -> String {
        format!(
            "        |0ms{}{}ms|   ({} buckets of {:.2}ms)",
            "-".repeat(buckets.saturating_sub(2)),
            ms(self.end_ns),
            buckets,
            self.end_ns as f64 / 1e6 / buckets.max(1) as f64,
        )
    }

    /// Per-device utilization timeline: one row per device, one char
    /// per bucket — `.` idle, `1`–`9` busy fraction, `x` down.
    pub fn utilization_timeline(&self, buckets: usize) -> String {
        let buckets = buckets.max(1);
        let end = self.end_ns.max(1);
        let width = (end as u128 / buckets as u128).max(1);
        let mut out = String::from("per-device utilization\n");
        out.push_str(&self.bucket_axis(buckets));
        out.push('\n');
        for d in 0..self.device_count() as u64 {
            let mut row = String::new();
            for b in 0..buckets {
                let lo = (b as u128 * width) as u64;
                let hi = (lo as u128 + width) as u64;
                let busy: u128 = self
                    .busy_spans
                    .iter()
                    .filter(|s| s.0 == d)
                    .map(|s| s.2.min(hi).saturating_sub(s.1.max(lo)) as u128)
                    .sum();
                let down = self
                    .fault_spans
                    .iter()
                    .any(|&(fd, from, to)| fd == d && from < hi && lo < to);
                let frac = busy as f64 / width as f64;
                row.push(if busy == 0 && down {
                    'x'
                } else if busy == 0 {
                    '.'
                } else {
                    char::from_digit((frac * 9.0).ceil().clamp(1.0, 9.0) as u32, 10).unwrap()
                });
            }
            out.push_str(&format!("dev {d:<3} {row}\n"));
        }
        out
    }

    /// Incident timeline: outages vs windowed SLO attainment vs
    /// autoscaler actions vs drops, one char per bucket.
    pub fn incident_timeline(&self, buckets: usize, slo_ns: u64) -> String {
        let buckets = buckets.max(1);
        let end = self.end_ns.max(1);
        let width = (end as u128 / buckets as u128).max(1);
        let bucket_of = |t: u64| ((t as u128 / width) as usize).min(buckets - 1);
        // Completion events: (completion time, met-SLO).
        let dones: Vec<(u64, bool)> = self
            .completed_spans()
            .filter_map(|s| match s.outcome {
                SpanOutcome::Done { e2e_ns, .. } => {
                    Some((s.arrival_ns + e2e_ns, e2e_ns <= slo_ns))
                }
                _ => None,
            })
            .collect();
        let mut faults = String::new();
        let mut attain = String::new();
        let mut scaler = String::new();
        let mut drops = String::new();
        for b in 0..buckets {
            let lo = (b as u128 * width) as u64;
            let hi = (lo as u128 + width) as u64;
            let down = self.fault_spans.iter().any(|&(_, from, to)| from < hi && lo < to);
            faults.push(if down { '#' } else { '.' });
            let (mut n, mut ok) = (0u64, 0u64);
            for &(t, met) in &dones {
                if lo <= t && t < hi {
                    n += 1;
                    ok += u64::from(met);
                }
            }
            attain.push(if n == 0 {
                ' '
            } else {
                char::from_digit(((ok as f64 / n as f64) * 9.0).floor() as u32, 10).unwrap()
            });
            let up = self.scale_up_ts.iter().any(|&t| bucket_of(t) == b);
            let dn = self.scale_down_ts.iter().any(|&t| bucket_of(t) == b);
            scaler.push(match (up, dn) {
                (true, true) => '*',
                (true, false) => '+',
                (false, true) => '-',
                (false, false) => '.',
            });
            drops.push(if self.drop_ts.iter().any(|&t| bucket_of(t) == b) { 'x' } else { '.' });
        }
        let mut out = String::from("incident timeline\n");
        out.push_str(&self.bucket_axis(buckets));
        out.push('\n');
        out.push_str(&format!("outage  {faults}   ('#' = some device down)\n"));
        out.push_str(&format!(
            "attain  {attain}   (0-9 = windowed SLO attainment x9, slo={:.2}ms)\n",
            slo_ns as f64 / 1e6
        ));
        out.push_str(&format!("scaler  {scaler}   ('+' up, '-' down/retire)\n"));
        out.push_str(&format!("drops   {drops}   ('x' = request dropped)\n"));
        // Overload-protection rows, only when the trace shows any
        // activity: admission shedding, breaker transitions, brownout
        // (degraded-mode) windows.
        if self.has_overload_activity() {
            let mut shed = String::new();
            let mut brkr = String::new();
            let mut brown = String::new();
            for b in 0..buckets {
                let lo = (b as u128 * width) as u64;
                let hi = (lo as u128 + width) as u64;
                shed.push(if self.reject_ts.iter().any(|&t| lo <= t && t < hi) {
                    'r'
                } else {
                    '.'
                });
                let trip = self.breaker_trip_ts.iter().any(|&t| lo <= t && t < hi);
                let close = self.breaker_close_ts.iter().any(|&t| lo <= t && t < hi);
                brkr.push(match (trip, close) {
                    (true, true) => '*',
                    (true, false) => 'B',
                    (false, true) => 'o',
                    (false, false) => '.',
                });
                brown.push(
                    if self.brownout_spans.iter().any(|&(from, to)| from < hi && lo < to) {
                        '~'
                    } else {
                        '.'
                    },
                );
            }
            out.push_str(&format!("shed    {shed}   ('r' = admission reject)\n"));
            out.push_str(&format!("breaker {brkr}   ('B' trip, 'o' close, '*' both)\n"));
            out.push_str(&format!("brown   {brown}   ('~' = fleet degraded)\n"));
        }
        // Expert-sharding rows, same gating discipline: replica moves
        // and no-replica drops against the outage/drop rows above.
        if self.has_shard_activity() {
            let mut replic = String::new();
            let mut norepl = String::new();
            for b in 0..buckets {
                let lo = (b as u128 * width) as u64;
                let hi = (lo as u128 + width) as u64;
                let add = self.replica_add_ts.iter().any(|&t| lo <= t && t < hi);
                let drop = self.replica_drop_ts.iter().any(|&t| lo <= t && t < hi);
                replic.push(match (add, drop) {
                    (true, true) => '*',
                    (true, false) => '+',
                    (false, true) => '-',
                    (false, false) => '.',
                });
                norepl.push(if self.no_replica_ts.iter().any(|&t| lo <= t && t < hi) {
                    'x'
                } else {
                    '.'
                });
            }
            out.push_str(&format!("replic  {replic}   ('+' add, '-' drop, '*' both)\n"));
            out.push_str(&format!("norepl  {norepl}   ('x' = no live replica)\n"));
        }
        out
    }

    /// Full report: header, breakdown table, both timelines, and the
    /// reconciliation line the acceptance criteria check.
    pub fn render(&self, slo: Option<Duration>, buckets: usize) -> String {
        let e2e: Vec<u64> = {
            let mut v: Vec<u64> = self
                .completed_spans()
                .filter_map(|s| match s.outcome {
                    SpanOutcome::Done { e2e_ns, .. } => Some(e2e_ns),
                    _ => None,
                })
                .collect();
            v.sort_unstable();
            v
        };
        let slo_ns = slo.map_or_else(|| pct(&e2e, 99.0), |d| d.as_nanos() as u64);
        let mut out = format!(
            "trace: policy={} seed={} devices={} horizon={}ms\n\
             spans: {} admitted, {} completed, {} dropped, {} rejected, \
             {} dispatched copies, makespan={}ms\n",
            self.policy,
            self.seed,
            self.device_count(),
            ms(self.horizon_ns),
            self.spans.len(),
            self.completed_count(),
            self.dropped_count(),
            self.rejected_count(),
            self.total_attempts(),
            ms(self.makespan_ns.max(self.end_ns)),
        );
        if self.has_shard_activity() {
            out.push_str(&format!(
                "shard: {} routed, {} rerouted, {} expert-dropped, {} no-replica, \
                 {} transfer records, {} replica moves\n",
                self.route_count.max(self.shard_routed),
                self.reroute_count,
                self.expert_drop_count,
                self.no_replica_ts.len(),
                self.xfer_count,
                self.replica_add_ts.len() + self.replica_drop_ts.len(),
            ));
        }
        if let Some(err) = &self.truncation {
            out.push_str(&format!(
                "WARNING: truncated trace — {} line(s) skipped ({err}); \
                 figures cover the valid prefix only\n",
                self.skipped_lines
            ));
        }
        out.push('\n');
        out.push_str(&self.breakdown_table().render());
        out.push_str("(*padding is a sub-part of service; queue + service + backoff \
                      + penalty == e2e per request)\n\n");
        out.push_str(&self.utilization_timeline(buckets));
        out.push('\n');
        out.push_str(&self.incident_timeline(buckets, slo_ns));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{DispatchWhy, JsonlSink, TraceRecord, TraceSink};

    fn mini_trace() -> String {
        let mut s = JsonlSink::new(Vec::new());
        let m = 1_000_000u64;
        s.record(0, TraceRecord::Meta {
            devices: 2,
            horizon_ns: 10 * m,
            seed: 1,
            policy: "jsq",
            experts: 0,
            max_wait_ns: m,
        });
        s.record(0, TraceRecord::Arrival { req: 0, hint: 0 });
        s.record(0, TraceRecord::Dispatch {
            req: 0,
            hedge: false,
            why: DispatchWhy::Arrive,
            device: 0,
            load: 1,
        });
        s.record(0, TraceRecord::BatchOpen {
            device: 0,
            size: 2,
            padding: 1,
            service_ns: 3 * m,
            reqs: vec![0],
        });
        s.record(2 * m, TraceRecord::DeviceFail { device: 0, lost_batch: true, orphans: 1 });
        s.record(2 * m, TraceRecord::Dispatch {
            req: 0,
            hedge: false,
            why: DispatchWhy::Failover,
            device: 1,
            load: 1,
        });
        s.record(2 * m, TraceRecord::BatchOpen {
            device: 1,
            size: 2,
            padding: 1,
            service_ns: 3 * m,
            reqs: vec![0],
        });
        s.record(5 * m, TraceRecord::BatchDone {
            device: 1,
            size: 2,
            padding: 1,
            service_ns: 3 * m,
            done: vec![0],
        });
        s.record(5 * m, TraceRecord::Done {
            req: 0,
            device: 1,
            e2e_ns: 5 * m,
            queue_ns: 0,
            service_ns: 3 * m,
            hedge: false,
        });
        s.record(6 * m, TraceRecord::DeviceRepair { device: 0, parked: 0 });
        s.record(10 * m, TraceRecord::Summary {
            admitted: 1,
            completed: 1,
            dropped: 0,
            makespan_ns: 5 * m,
        });
        String::from_utf8(s.finish().unwrap()).unwrap()
    }

    #[test]
    fn reconstructs_spans_and_components() {
        let a = analyze(&mini_trace()).unwrap();
        assert_eq!(a.spans.len(), 1);
        assert_eq!(a.completed_count(), 1);
        assert_eq!(a.total_attempts(), 2);
        let s = &a.spans[0];
        // Failover penalty: 5ms e2e − 3ms service − 0 queue = 2ms lost
        // to the failed first attempt.
        assert_eq!(s.failover_penalty_ns(), 2_000_000);
        // Padding share of the completing 2-slot batch: 3ms·1/2.
        assert_eq!(s.pad_ns, 1_500_000);
        assert_eq!(a.fault_spans, vec![(0, 2_000_000, 6_000_000)]);
        // The lost batch's busy span is clipped at the failure.
        assert!(a.busy_spans.contains(&(0, 0, 2_000_000)));
        assert_eq!(a.admitted, 1);
        assert_eq!(a.makespan_ns, 5_000_000);
    }

    #[test]
    fn renders_tables_and_timelines() {
        let a = analyze(&mini_trace()).unwrap();
        let out = a.render(Some(Duration::from_millis(4)), 20);
        assert!(out.contains("latency breakdown"));
        assert!(out.contains("failover penalty"));
        assert!(out.contains("incident timeline"));
        assert!(out.contains("outage"));
        // Utilization: device 0 shows down buckets.
        let util = a.utilization_timeline(10);
        assert!(util.contains('x'), "{util}");
        // Incident: outage row must mark the [2ms, 6ms) window.
        let inc = a.incident_timeline(10, 4_000_000);
        assert!(inc.contains('#'), "{inc}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(analyze("{\"no_kind\":1}\n").is_err());
        assert!(analyze("{\"t\":0,\"kind\":\"done\",\"req\":5,\"e2e_ns\":1}\n").is_err());
        // Unknown kinds pass through (forward compatibility).
        assert!(analyze("{\"t\":0,\"kind\":\"new_thing\",\"x\":1}\n").is_ok());
        // Empty trace is fine.
        let empty = analyze("").unwrap();
        assert_eq!(empty.completed_count(), 0);
        assert_eq!(pct(&[], 50.0), 0);
    }

    #[test]
    fn truncated_tail_is_tolerated_after_a_valid_prefix() {
        // Cut the mini trace mid-line (a run killed mid-write): the
        // valid prefix must analyze, the damage must be counted and
        // surfaced — not turned into a hard error.
        let full = mini_trace();
        // Cut inside the final record's "kind" key so the ragged line
        // is genuinely unparseable (the schema puts "t","kind" first,
        // so a tail cut that leaves them intact still parses).
        let cut = &full[..full.rfind("\"kind\"").unwrap() + 3];
        let a = analyze(cut).expect("valid prefix must analyze");
        assert!(a.truncation.is_some(), "the ragged tail must be reported");
        assert_eq!(a.skipped_lines, 1, "exactly the cut line is skipped");
        assert_eq!(a.spans.len(), 1, "prefix spans survive");
        let out = a.render(None, 10);
        assert!(out.contains("WARNING: truncated trace"), "{out}");
        assert!(out.contains("1 line(s) skipped"), "{out}");
        // A clean trace renders no warning.
        assert!(!analyze(&full).unwrap().render(None, 10).contains("WARNING"));
        // But garbage from the very first record is still an error,
        // not a "truncated" empty analysis.
        assert!(analyze("not json at all\n").is_err());
    }

    fn overload_trace() -> String {
        let m = 1_000_000u64;
        let mut s = JsonlSink::new(Vec::new());
        s.record(0, TraceRecord::Meta {
            devices: 1,
            horizon_ns: 10 * m,
            seed: 1,
            policy: "jsq",
            experts: 0,
            max_wait_ns: m,
        });
        s.record(0, TraceRecord::Arrival { req: 0, hint: 0 });
        s.record(0, TraceRecord::Reject { req: 0, class: 2, why: "queue" });
        s.record(m, TraceRecord::BreakerTrip { device: 0, streak: 3 });
        s.record(2 * m, TraceRecord::BreakerProbe { device: 0 });
        s.record(2 * m, TraceRecord::BreakerClose { device: 0 });
        s.record(3 * m, TraceRecord::BrownoutEnter { attain_ppm: 500_000 });
        s.record(7 * m, TraceRecord::BrownoutExit { attain_ppm: 990_000 });
        s.record(9 * m, TraceRecord::OverloadSummary {
            rejected: 1,
            rejected_rate: 0,
            rejected_queue: 1,
            breaker_trips: 1,
            breaker_closes: 1,
            brownout_enters: 1,
            degraded_completions: 0,
        });
        s.record(10 * m, TraceRecord::Summary {
            admitted: 1,
            completed: 0,
            dropped: 0,
            makespan_ns: 10 * m,
        });
        String::from_utf8(s.finish().unwrap()).unwrap()
    }

    #[test]
    fn overload_records_reconstruct_and_render() {
        let a = analyze(&overload_trace()).unwrap();
        assert_eq!(a.rejected_count(), 1);
        assert_eq!(a.spans[0].outcome, SpanOutcome::Rejected { class: 2 });
        assert_eq!(a.reject_ts, vec![0]);
        assert_eq!(a.breaker_trip_ts, vec![1_000_000]);
        assert_eq!(a.breaker_close_ts, vec![2_000_000]);
        assert_eq!(a.brownout_spans, vec![(3_000_000, 7_000_000)]);
        assert_eq!(a.rejected, 1, "overload_summary record parsed");
        assert!(a.has_overload_activity());
        let inc = a.incident_timeline(10, 1_000_000);
        assert!(inc.contains("shed"), "{inc}");
        assert!(inc.contains('r'), "{inc}");
        assert!(inc.contains('B'), "{inc}");
        assert!(inc.contains('o'), "{inc}");
        assert!(inc.contains('~'), "{inc}");
        let out = a.render(None, 10);
        assert!(out.contains("1 rejected"), "{out}");
        // Fault-era traces stay overload-free: no extra rows.
        let plain = analyze(&mini_trace()).unwrap();
        assert!(!plain.has_overload_activity());
        assert!(!plain.incident_timeline(10, 1_000_000).contains("shed"));
    }

    #[test]
    fn shard_records_reconstruct_and_render() {
        let m = 1_000_000u64;
        let mut s = JsonlSink::new(Vec::new());
        s.record(0, TraceRecord::Meta {
            devices: 2,
            horizon_ns: 10 * m,
            seed: 1,
            policy: "jsq",
            experts: 4,
            max_wait_ns: m,
        });
        s.record(0, TraceRecord::Arrival { req: 0, hint: 1 });
        s.record(0, TraceRecord::Route { req: 0, expert: 2, primary: 1, rerouted: true });
        s.record(0, TraceRecord::Xfer { req: 0, device: 1, remote: 1, xfer_ns: 500 });
        s.record(m, TraceRecord::Arrival { req: 1, hint: 0 });
        s.record(m, TraceRecord::Route { req: 1, expert: -1, primary: 0, rerouted: false });
        s.record(2 * m, TraceRecord::Arrival { req: 2, hint: 3 });
        s.record(2 * m, TraceRecord::Route { req: 2, expert: 3, primary: 3, rerouted: false });
        s.record(2 * m, TraceRecord::NoReplica { req: 2, expert: 3 });
        s.record(2 * m, TraceRecord::Drop { req: 2, attempts: 1 });
        s.record(4 * m, TraceRecord::ReplicaAdd { expert: 3, device: 0 });
        s.record(5 * m, TraceRecord::ReplicaDrop { expert: 1, device: 1 });
        s.record(9 * m, TraceRecord::ShardSummary {
            routed: 3,
            rerouted: 1,
            expert_drops: 1,
            no_replica: 1,
            transfers: 1,
            replica_adds: 1,
            replica_drops: 1,
        });
        s.record(10 * m, TraceRecord::Summary {
            admitted: 3,
            completed: 0,
            dropped: 1,
            makespan_ns: 10 * m,
        });
        let text = String::from_utf8(s.finish().unwrap()).unwrap();
        let a = analyze(&text).unwrap();
        assert_eq!(a.route_count, 3);
        assert_eq!(a.reroute_count, 1);
        assert_eq!(a.expert_drop_count, 1, "expert=-1 routes are expert drops");
        assert_eq!(a.xfer_count, 1);
        assert_eq!(a.no_replica_ts, vec![2_000_000]);
        assert_eq!(a.replica_add_ts, vec![4_000_000]);
        assert_eq!(a.replica_drop_ts, vec![5_000_000]);
        assert_eq!(a.shard_routed, 3);
        assert!(a.has_shard_activity());
        let inc = a.incident_timeline(10, m);
        assert!(inc.contains("replic"), "{inc}");
        assert!(inc.contains('+'), "{inc}");
        assert!(inc.contains("norepl"), "{inc}");
        let out = a.render(None, 10);
        assert!(out.contains("shard: 3 routed"), "{out}");
        // Shard-free traces keep their old shape: no extra rows.
        let plain = analyze(&mini_trace()).unwrap();
        assert!(!plain.has_shard_activity());
        assert!(!plain.incident_timeline(10, m).contains("replic"));
        assert!(!plain.render(None, 10).contains("shard:"));
    }

    #[test]
    fn unclosed_brownout_window_closes_at_trace_end() {
        let m = 1_000_000u64;
        let mut s = JsonlSink::new(Vec::new());
        s.record(2 * m, TraceRecord::BrownoutEnter { attain_ppm: 100_000 });
        s.record(5 * m, TraceRecord::Summary {
            admitted: 0,
            completed: 0,
            dropped: 0,
            makespan_ns: 5 * m,
        });
        let text = String::from_utf8(s.finish().unwrap()).unwrap();
        let a = analyze(&text).unwrap();
        assert_eq!(a.brownout_spans, vec![(2_000_000, 5_000_000)]);
    }
}
