//! Process-wide work counters: how much *expensive* work (GA fitness
//! evaluations, cycle-simulator timeline walks, evaluation-table
//! builds) and how much design-cache traffic (hits / misses / stores)
//! this process has performed.
//!
//! These exist to make the design-cache contract *assertable*: a
//! warm-cache `deploy_many` / `serving_study` run must perform **zero**
//! GA evaluations and **zero** cycle-sim walks (ISSUE 4 acceptance).
//! The counters are plain process-global relaxed atomics — negligible
//! next to the work they count (one add per GA memo miss / per
//! timeline walk). Tests that assert deltas must serialize against
//! other counter-touching tests in the same process (see
//! `rust/tests/design_cache.rs`, which guards every such test with a
//! file-local mutex; the lib test binary never asserts on them).
//!
//! Since ISSUE 7 this is the observability registry: the snapshot
//! renders through the shared JSON writer ([`WorkSnapshot::to_json`])
//! and is embedded by `ubimoe cache stats` and the traced `ubimoe
//! serve` path. It is deliberately **not** part of trace files or
//! `FleetReport` — process-global counters are shared across threads,
//! so baking them into per-run artifacts would break the byte- and
//! bit-determinism contracts.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::json::JsonObj;

static GA_TRUE_EVALS: AtomicU64 = AtomicU64::new(0);
static SIM_WALKS: AtomicU64 = AtomicU64::new(0);
static EVAL_TABLE_BUILDS: AtomicU64 = AtomicU64::new(0);
static CACHE_HITS: AtomicU64 = AtomicU64::new(0);
static CACHE_MISSES: AtomicU64 = AtomicU64::new(0);
static CACHE_STORES: AtomicU64 = AtomicU64::new(0);
static DES_RUNS: AtomicU64 = AtomicU64::new(0);
static DES_EVENTS: AtomicU64 = AtomicU64::new(0);

/// One GA fitness evaluation that actually ran the model (a genome-memo
/// miss in `has::eval::MemoFcGa`). Memo hits are deliberately not
/// counted — they are free and the cache contract is about real work.
#[inline]
pub fn count_ga_true_eval() {
    GA_TRUE_EVALS.fetch_add(1, Ordering::Relaxed);
}

/// One cycle-simulator timeline walk (`sim::engine` — `simulate`,
/// `simulate_sequential`, or a `latency_surface` pass).
#[inline]
pub fn count_sim_walk() {
    SIM_WALKS.fetch_add(1, Ordering::Relaxed);
}

/// One `has::eval::EvalTables` build (a few hundred model calls).
#[inline]
pub fn count_table_build() {
    EVAL_TABLE_BUILDS.fetch_add(1, Ordering::Relaxed);
}

#[inline]
pub fn count_cache_hit() {
    CACHE_HITS.fetch_add(1, Ordering::Relaxed);
}

#[inline]
pub fn count_cache_miss() {
    CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
}

#[inline]
pub fn count_cache_store() {
    CACHE_STORES.fetch_add(1, Ordering::Relaxed);
}

/// One completed DES event loop (`serve::simulate_fleet`), with the
/// number of events it processed (sampler ticks already compensated
/// out, so the figure matches `FleetReport::events`). The fleet-report
/// memo contract is asserted on these: a memo-warm plan rerun performs
/// **zero** DES runs and **zero** DES events (ISSUE 10 acceptance).
#[inline]
pub fn count_des_run(events: u64) {
    DES_RUNS.fetch_add(1, Ordering::Relaxed);
    DES_EVENTS.fetch_add(events, Ordering::Relaxed);
}

/// Point-in-time reading of every counter.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkSnapshot {
    pub ga_true_evals: u64,
    pub sim_walks: u64,
    pub table_builds: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_stores: u64,
    pub des_runs: u64,
    pub des_events: u64,
}

impl WorkSnapshot {
    /// Work performed since `since` (wrapping-safe; counters only grow).
    pub fn delta(&self, since: &WorkSnapshot) -> WorkSnapshot {
        WorkSnapshot {
            ga_true_evals: self.ga_true_evals.wrapping_sub(since.ga_true_evals),
            sim_walks: self.sim_walks.wrapping_sub(since.sim_walks),
            table_builds: self.table_builds.wrapping_sub(since.table_builds),
            cache_hits: self.cache_hits.wrapping_sub(since.cache_hits),
            cache_misses: self.cache_misses.wrapping_sub(since.cache_misses),
            cache_stores: self.cache_stores.wrapping_sub(since.cache_stores),
            des_runs: self.des_runs.wrapping_sub(since.des_runs),
            des_events: self.des_events.wrapping_sub(since.des_events),
        }
    }

    /// True iff no GA evaluation, no cycle-sim walk and no table build
    /// happened — the warm-cache "zero expensive work" predicate.
    pub fn no_search_work(&self) -> bool {
        self.ga_true_evals == 0 && self.sim_walks == 0 && self.table_builds == 0
    }

    /// True iff no DES event loop ran — the fleet-report memo
    /// "zero simulation work" predicate (ISSUE 10).
    pub fn no_des_work(&self) -> bool {
        self.des_runs == 0 && self.des_events == 0
    }

    /// One-line JSON object via the shared writer
    /// ([`crate::obs::json::JsonObj`]).
    pub fn to_json(&self) -> String {
        let mut o = JsonObj::new();
        o.u64("ga_true_evals", self.ga_true_evals)
            .u64("sim_walks", self.sim_walks)
            .u64("table_builds", self.table_builds)
            .u64("cache_hits", self.cache_hits)
            .u64("cache_misses", self.cache_misses)
            .u64("cache_stores", self.cache_stores)
            .u64("des_runs", self.des_runs)
            .u64("des_events", self.des_events);
        o.finish()
    }

    /// Compact human-readable line for CLI embedding.
    pub fn render(&self) -> String {
        format!(
            "ga_evals={} sim_walks={} table_builds={} cache hit/miss/store={}/{}/{} \
             des runs/events={}/{}",
            self.ga_true_evals,
            self.sim_walks,
            self.table_builds,
            self.cache_hits,
            self.cache_misses,
            self.cache_stores,
            self.des_runs,
            self.des_events
        )
    }
}

/// Snapshot the process-wide counters.
pub fn snapshot() -> WorkSnapshot {
    WorkSnapshot {
        ga_true_evals: GA_TRUE_EVALS.load(Ordering::Relaxed),
        sim_walks: SIM_WALKS.load(Ordering::Relaxed),
        table_builds: EVAL_TABLE_BUILDS.load(Ordering::Relaxed),
        cache_hits: CACHE_HITS.load(Ordering::Relaxed),
        cache_misses: CACHE_MISSES.load(Ordering::Relaxed),
        cache_stores: CACHE_STORES.load(Ordering::Relaxed),
        des_runs: DES_RUNS.load(Ordering::Relaxed),
        des_events: DES_EVENTS.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deltas_accumulate() {
        // Counters are process-global and other lib tests run
        // concurrently, so only assert monotonicity of *our own*
        // increments, never absolute values.
        let before = snapshot();
        count_ga_true_eval();
        count_sim_walk();
        count_sim_walk();
        count_table_build();
        count_cache_hit();
        count_cache_miss();
        count_cache_store();
        count_des_run(17);
        let d = snapshot().delta(&before);
        assert!(d.ga_true_evals >= 1);
        assert!(d.sim_walks >= 2);
        assert!(d.table_builds >= 1);
        assert!(d.cache_hits >= 1 && d.cache_misses >= 1 && d.cache_stores >= 1);
        assert!(d.des_runs >= 1 && d.des_events >= 17);
        assert!(!d.no_search_work());
        assert!(!d.no_des_work());
        assert!(WorkSnapshot::default().no_search_work());
        assert!(WorkSnapshot::default().no_des_work());
    }

    #[test]
    fn snapshot_renders_json_and_text() {
        let s = WorkSnapshot { ga_true_evals: 1, cache_hits: 2, ..Default::default() };
        assert_eq!(
            s.to_json(),
            r#"{"ga_true_evals":1,"sim_walks":0,"table_builds":0,"cache_hits":2,"cache_misses":0,"cache_stores":0,"des_runs":0,"des_events":0}"#
        );
        assert!(s.render().contains("ga_evals=1"));
        assert!(s.render().contains("hit/miss/store=2/0/0"));
        assert!(s.render().contains("des runs/events=0/0"));
    }
}
