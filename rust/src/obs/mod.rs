//! Observability: deterministic telemetry for the serving DES and
//! shared JSON emission for every artifact this repo prints.
//!
//! Submodules:
//!
//! - [`json`] — the one serde-free JSON writer (and its line reader):
//!   bench rows, trace records, and registry snapshots all serialize
//!   here, so escaping policy exists exactly once.
//! - [`trace`] — typed, virtual-ns-stamped event records
//!   ([`TraceRecord`]) and sinks ([`TraceSink`]; JSONL via
//!   [`JsonlSink`]).
//! - [`sampler`] — windowed per-device + fleet gauges emitted from a
//!   heap-scheduled `SampleTick`, rendered to CSV.
//! - [`analyze`] — offline span reconstruction and the
//!   latency-breakdown / timeline report behind
//!   `ubimoe trace analyze`.
//! - [`registry`] — process-wide work counters (moved from
//!   `util::counters`).
//!
//! Design invariants (proptested in `rust/tests/serve_properties.rs`):
//! observation never perturbs the simulation (`FleetReport` is
//! bit-identical with tracing/sampling on or off), and fixed
//! (config, seed) yields byte-identical trace and time-series files —
//! no wall clock, no map iteration order, no floats in the trace.

pub mod analyze;
pub mod json;
pub mod registry;
pub mod sampler;
pub mod trace;

pub use sampler::{SampleRow, SamplerConfig, TimeSeries};
pub use trace::{DispatchWhy, JsonlSink, NullSink, TraceRecord, TraceSink};

/// The observation hookup handed to `serve::simulate_fleet_observed`:
/// both halves optional and `None` costs nothing (records are never
/// constructed, the sampler never schedules its tick).
pub struct Observer<'a> {
    pub trace: Option<&'a mut dyn TraceSink>,
    pub series: Option<&'a mut TimeSeries>,
}

impl<'a> Observer<'a> {
    /// Observe nothing (what `simulate_fleet` passes).
    pub fn none() -> Observer<'static> {
        Observer { trace: None, series: None }
    }

    pub fn with_trace(trace: &'a mut dyn TraceSink) -> Observer<'a> {
        Observer { trace: Some(trace), series: None }
    }
}
