//! Typed, virtual-time-stamped event tracing for the serving DES.
//!
//! The DES emits one [`TraceRecord`] per consequential event — every
//! arrival, dispatch decision, batch open/flush/completion, timeout,
//! retry, hedge, device fail/repair, autoscaler action, and drop —
//! into a [`TraceSink`]. Records carry request ids, so a full
//! per-request span (arrival → attempts → completion, including
//! failovers and hedges) is reconstructible offline
//! ([`crate::obs::analyze`]).
//!
//! Contracts:
//!
//! - **Zero cost when off.** The DES holds an `Option<&mut dyn
//!   TraceSink>`; with `None`, records are never even *constructed*
//!   (emission sites build them inside a closure that only runs when a
//!   sink is present). The tracing-on/off bit-identity proptest in
//!   `rust/tests/serve_properties.rs` pins the stronger property: a
//!   sink never changes the simulation.
//! - **Byte determinism.** Timestamps are the DES's integer virtual
//!   nanoseconds — never the wall clock — and serialization is
//!   [`crate::obs::json::JsonObj`] with a fixed field order, so a
//!   fixed (config, seed) yields a byte-identical trace file (CI
//!   diffs two same-seed runs).
//!
//! The line format is flat JSONL: every line is one object with `"t"`
//! (virtual ns) and `"kind"` first, then kind-specific fields. The
//! schema is versioned by the leading `meta` record's `schema` field;
//! see EXPERIMENTS.md §Observability for the field tables.

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;

use crate::obs::json::JsonObj;

/// Trace schema version, bumped on any breaking field change.
pub const TRACE_SCHEMA: u64 = 1;

/// Why a request copy was handed to the dispatcher.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchWhy {
    /// Fresh arrival (first attempt).
    Arrive,
    /// Re-dispatch of a copy orphaned by a device failure.
    Failover,
    /// Retry after an attempt deadline expired (post-backoff).
    Retry,
    /// Speculative hedge copy.
    Hedge,
    /// Copy parked during a full outage, flushed on repair/scale-up.
    Parked,
}

impl DispatchWhy {
    pub fn as_str(self) -> &'static str {
        match self {
            DispatchWhy::Arrive => "arrive",
            DispatchWhy::Failover => "failover",
            DispatchWhy::Retry => "retry",
            DispatchWhy::Hedge => "hedge",
            DispatchWhy::Parked => "parked",
        }
    }
}

/// One trace event. Field names and order here define the JSONL
/// schema ([`TraceRecord::to_line`]).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceRecord {
    /// First line of every trace: run shape, for self-describing files.
    Meta {
        devices: u64,
        horizon_ns: u64,
        seed: u64,
        policy: &'static str,
        experts: u64,
        max_wait_ns: u64,
    },
    /// A request was admitted (open-loop schedule or closed-loop user).
    Arrival { req: u64, hint: u64 },
    /// The dispatcher routed one copy of a request. `device` is `-1`
    /// when the whole fleet was down and the copy was parked; `load`
    /// is the chosen device's queue+in-flight signal *after* the add —
    /// the policy input that decided the pick.
    Dispatch { req: u64, hedge: bool, why: DispatchWhy, device: i64, load: u64 },
    /// A device started executing a batch.
    BatchOpen { device: u64, size: u64, padding: u64, service_ns: u64, reqs: Vec<u64> },
    /// A max-wait flush deadline fired live (undersized batch forced
    /// out).
    Flush { device: u64 },
    /// A batch finished; `done` lists the requests settled by it
    /// (copies whose request already settled elsewhere are absent).
    BatchDone { device: u64, size: u64, padding: u64, service_ns: u64, done: Vec<u64> },
    /// One request settled successfully.
    Done { req: u64, device: u64, e2e_ns: u64, queue_ns: u64, service_ns: u64, hedge: bool },
    /// SEU corruption: the batch re-executes on the same device.
    SeuRerun { device: u64, service_ns: u64 },
    /// Fault injection took a device down. `lost_batch` is whether an
    /// in-flight batch died with it; `orphans` counts the live request
    /// copies that immediately re-dispatched (failover).
    DeviceFail { device: u64, lost_batch: bool, orphans: u64 },
    /// Fault injection brought a device back; `parked` counts the
    /// copies flushed from the fleet-down parking lot.
    DeviceRepair { device: u64, parked: u64 },
    /// A per-attempt deadline expired before the attempt settled.
    AttemptTimeout { req: u64, attempt: u64 },
    /// A timed-out request was rescheduled: attempt `attempt` failed,
    /// the next copy dispatches after `backoff_ns`.
    Retry { req: u64, attempt: u64, backoff_ns: u64 },
    /// A request exhausted its attempt budget and was dropped.
    Drop { req: u64, attempts: u64 },
    /// Autoscaler controller tick: the window signal it saw and the
    /// fleet size it asked for. `attain_ppm` is windowed SLO
    /// attainment in parts-per-million (integer, for byte
    /// determinism); `calm` is the controller's consecutive-calm
    /// window streak.
    ScaleTick { arrivals: u64, attain_ppm: u64, backlog: u64, active: u64, desired: u64, calm: u64 },
    /// A replica came up (`mode`: "undrain" | "retool" | "spawn").
    ScaleUp { slot: u64, mode: &'static str },
    /// A replica began draining.
    ScaleDown { slot: u64 },
    /// A draining replica finished its last batch and retired.
    Retire { slot: u64 },
    /// Admission control rejected an arrival at the fleet edge.
    /// `class` is the request's priority index (0 = interactive);
    /// `why` is a [`crate::serve::overload::RejectReason`] label
    /// (`"rate"` | `"queue"`).
    Reject { req: u64, class: u64, why: &'static str },
    /// A device's circuit breaker opened after `streak` consecutive
    /// attempt timeouts; the device leaves dispatch until a probe.
    BreakerTrip { device: u64, streak: u64 },
    /// A breaker's cooldown elapsed: the device half-opens and takes
    /// probe traffic again.
    BreakerProbe { device: u64 },
    /// A half-open breaker's probe succeeded: the device is fully
    /// back in dispatch.
    BreakerClose { device: u64 },
    /// The brownout controller degraded the fleet (devices swap onto
    /// the lower-bit-width service table). `attain_ppm` is the
    /// triggering window's attainment, rejects-as-misses, in
    /// parts-per-million (integer, for byte determinism).
    BrownoutEnter { attain_ppm: u64 },
    /// The brownout controller restored full-precision service.
    BrownoutExit { attain_ppm: u64 },
    /// Overload-machinery totals, emitted just before `Summary` on
    /// runs with overload protection active (matches
    /// `FleetReport::overload`). A separate record so the frozen
    /// `Summary` schema never changes shape.
    OverloadSummary {
        rejected: u64,
        rejected_rate: u64,
        rejected_queue: u64,
        breaker_trips: u64,
        breaker_closes: u64,
        brownout_enters: u64,
        degraded_completions: u64,
    },
    /// The top-k router assigned request `req` its serving expert.
    /// `expert` is `-1` when every routed expert was over capacity and
    /// the request degrades via expert-drop; `primary` is the original
    /// popularity draw and `rerouted` is 1 when capacity pushed the
    /// request onto a secondary expert.
    Route { req: u64, expert: i64, primary: u64, rerouted: bool },
    /// Interconnect transfers charged to request `req`: `remote`
    /// secondary experts were not hosted on `device`, adding `xfer_ns`
    /// to the request's end-to-end latency.
    Xfer { req: u64, device: u64, remote: u64, xfer_ns: u64 },
    /// A request copy found no live device hosting its serving expert;
    /// primary copies drop here (counted into `FleetReport::dropped`).
    NoReplica { req: u64, expert: u64 },
    /// The rebalancer started hosting `expert` on `device` (re-home or
    /// hot-expert growth).
    ReplicaAdd { expert: u64, device: u64 },
    /// The rebalancer stopped routing `expert` to `device` (cold trim;
    /// queued work drains normally).
    ReplicaDrop { expert: u64, device: u64 },
    /// Shard-machinery totals, emitted just before `Summary` on runs
    /// with expert sharding active (matches `FleetReport::shard`). A
    /// separate record so the frozen `Summary` schema never changes
    /// shape (the `OverloadSummary` idiom).
    ShardSummary {
        routed: u64,
        rerouted: u64,
        expert_drops: u64,
        no_replica: u64,
        transfers: u64,
        replica_adds: u64,
        replica_drops: u64,
    },
    /// Last line: run totals (matches the `FleetReport`).
    Summary { admitted: u64, completed: u64, dropped: u64, makespan_ns: u64 },
}

impl TraceRecord {
    /// Stable record-kind tag (the `"kind"` field).
    pub fn kind(&self) -> &'static str {
        match self {
            TraceRecord::Meta { .. } => "meta",
            TraceRecord::Arrival { .. } => "arrival",
            TraceRecord::Dispatch { .. } => "dispatch",
            TraceRecord::BatchOpen { .. } => "batch_open",
            TraceRecord::Flush { .. } => "flush",
            TraceRecord::BatchDone { .. } => "batch_done",
            TraceRecord::Done { .. } => "done",
            TraceRecord::SeuRerun { .. } => "seu_rerun",
            TraceRecord::DeviceFail { .. } => "device_fail",
            TraceRecord::DeviceRepair { .. } => "device_repair",
            TraceRecord::AttemptTimeout { .. } => "attempt_timeout",
            TraceRecord::Retry { .. } => "retry",
            TraceRecord::Drop { .. } => "drop",
            TraceRecord::ScaleTick { .. } => "scale_tick",
            TraceRecord::ScaleUp { .. } => "scale_up",
            TraceRecord::ScaleDown { .. } => "scale_down",
            TraceRecord::Retire { .. } => "retire",
            TraceRecord::Reject { .. } => "reject",
            TraceRecord::BreakerTrip { .. } => "breaker_trip",
            TraceRecord::BreakerProbe { .. } => "breaker_probe",
            TraceRecord::BreakerClose { .. } => "breaker_close",
            TraceRecord::BrownoutEnter { .. } => "brownout_enter",
            TraceRecord::BrownoutExit { .. } => "brownout_exit",
            TraceRecord::OverloadSummary { .. } => "overload_summary",
            TraceRecord::Route { .. } => "route",
            TraceRecord::Xfer { .. } => "xfer",
            TraceRecord::NoReplica { .. } => "no_replica",
            TraceRecord::ReplicaAdd { .. } => "replica_add",
            TraceRecord::ReplicaDrop { .. } => "replica_drop",
            TraceRecord::ShardSummary { .. } => "shard_summary",
            TraceRecord::Summary { .. } => "summary",
        }
    }

    /// Serialize to one JSONL line (no trailing newline).
    pub fn to_line(&self, t_ns: u64) -> String {
        let mut o = JsonObj::new();
        o.u64("t", t_ns).str("kind", self.kind());
        match self {
            TraceRecord::Meta { devices, horizon_ns, seed, policy, experts, max_wait_ns } => {
                o.u64("schema", TRACE_SCHEMA)
                    .u64("devices", *devices)
                    .u64("horizon_ns", *horizon_ns)
                    .u64("seed", *seed)
                    .str("policy", policy)
                    .u64("experts", *experts)
                    .u64("max_wait_ns", *max_wait_ns);
            }
            TraceRecord::Arrival { req, hint } => {
                o.u64("req", *req).u64("hint", *hint);
            }
            TraceRecord::Dispatch { req, hedge, why, device, load } => {
                o.u64("req", *req)
                    .u64("hedge", u64::from(*hedge))
                    .str("why", why.as_str())
                    .i64("device", *device)
                    .u64("load", *load);
            }
            TraceRecord::BatchOpen { device, size, padding, service_ns, reqs } => {
                o.u64("device", *device)
                    .u64("size", *size)
                    .u64("padding", *padding)
                    .u64("service_ns", *service_ns)
                    .arr_u64("reqs", reqs);
            }
            TraceRecord::Flush { device } => {
                o.u64("device", *device);
            }
            TraceRecord::BatchDone { device, size, padding, service_ns, done } => {
                o.u64("device", *device)
                    .u64("size", *size)
                    .u64("padding", *padding)
                    .u64("service_ns", *service_ns)
                    .arr_u64("done", done);
            }
            TraceRecord::Done { req, device, e2e_ns, queue_ns, service_ns, hedge } => {
                o.u64("req", *req)
                    .u64("device", *device)
                    .u64("e2e_ns", *e2e_ns)
                    .u64("queue_ns", *queue_ns)
                    .u64("service_ns", *service_ns)
                    .u64("hedge", u64::from(*hedge));
            }
            TraceRecord::SeuRerun { device, service_ns } => {
                o.u64("device", *device).u64("service_ns", *service_ns);
            }
            TraceRecord::DeviceFail { device, lost_batch, orphans } => {
                o.u64("device", *device)
                    .u64("lost_batch", u64::from(*lost_batch))
                    .u64("orphans", *orphans);
            }
            TraceRecord::DeviceRepair { device, parked } => {
                o.u64("device", *device).u64("parked", *parked);
            }
            TraceRecord::AttemptTimeout { req, attempt } => {
                o.u64("req", *req).u64("attempt", *attempt);
            }
            TraceRecord::Retry { req, attempt, backoff_ns } => {
                o.u64("req", *req).u64("attempt", *attempt).u64("backoff_ns", *backoff_ns);
            }
            TraceRecord::Drop { req, attempts } => {
                o.u64("req", *req).u64("attempts", *attempts);
            }
            TraceRecord::ScaleTick { arrivals, attain_ppm, backlog, active, desired, calm } => {
                o.u64("arrivals", *arrivals)
                    .u64("attain_ppm", *attain_ppm)
                    .u64("backlog", *backlog)
                    .u64("active", *active)
                    .u64("desired", *desired)
                    .u64("calm", *calm);
            }
            TraceRecord::ScaleUp { slot, mode } => {
                o.u64("slot", *slot).str("mode", mode);
            }
            TraceRecord::ScaleDown { slot } => {
                o.u64("slot", *slot);
            }
            TraceRecord::Retire { slot } => {
                o.u64("slot", *slot);
            }
            TraceRecord::Reject { req, class, why } => {
                o.u64("req", *req).u64("class", *class).str("why", why);
            }
            TraceRecord::BreakerTrip { device, streak } => {
                o.u64("device", *device).u64("streak", *streak);
            }
            TraceRecord::BreakerProbe { device } => {
                o.u64("device", *device);
            }
            TraceRecord::BreakerClose { device } => {
                o.u64("device", *device);
            }
            TraceRecord::BrownoutEnter { attain_ppm } => {
                o.u64("attain_ppm", *attain_ppm);
            }
            TraceRecord::BrownoutExit { attain_ppm } => {
                o.u64("attain_ppm", *attain_ppm);
            }
            TraceRecord::OverloadSummary {
                rejected,
                rejected_rate,
                rejected_queue,
                breaker_trips,
                breaker_closes,
                brownout_enters,
                degraded_completions,
            } => {
                o.u64("rejected", *rejected)
                    .u64("rejected_rate", *rejected_rate)
                    .u64("rejected_queue", *rejected_queue)
                    .u64("breaker_trips", *breaker_trips)
                    .u64("breaker_closes", *breaker_closes)
                    .u64("brownout_enters", *brownout_enters)
                    .u64("degraded_completions", *degraded_completions);
            }
            TraceRecord::Route { req, expert, primary, rerouted } => {
                o.u64("req", *req)
                    .i64("expert", *expert)
                    .u64("primary", *primary)
                    .u64("rerouted", u64::from(*rerouted));
            }
            TraceRecord::Xfer { req, device, remote, xfer_ns } => {
                o.u64("req", *req)
                    .u64("device", *device)
                    .u64("remote", *remote)
                    .u64("xfer_ns", *xfer_ns);
            }
            TraceRecord::NoReplica { req, expert } => {
                o.u64("req", *req).u64("expert", *expert);
            }
            TraceRecord::ReplicaAdd { expert, device } => {
                o.u64("expert", *expert).u64("device", *device);
            }
            TraceRecord::ReplicaDrop { expert, device } => {
                o.u64("expert", *expert).u64("device", *device);
            }
            TraceRecord::ShardSummary {
                routed,
                rerouted,
                expert_drops,
                no_replica,
                transfers,
                replica_adds,
                replica_drops,
            } => {
                o.u64("routed", *routed)
                    .u64("rerouted", *rerouted)
                    .u64("expert_drops", *expert_drops)
                    .u64("no_replica", *no_replica)
                    .u64("transfers", *transfers)
                    .u64("replica_adds", *replica_adds)
                    .u64("replica_drops", *replica_drops);
            }
            TraceRecord::Summary { admitted, completed, dropped, makespan_ns } => {
                o.u64("admitted", *admitted)
                    .u64("completed", *completed)
                    .u64("dropped", *dropped)
                    .u64("makespan_ns", *makespan_ns);
            }
        }
        o.finish()
    }
}

/// Receiver for trace records. Implementations must not observe wall
/// time or otherwise feed anything back into the simulation.
pub trait TraceSink {
    fn record(&mut self, t_ns: u64, rec: TraceRecord);
}

/// Discards everything (the explicit no-op sink; the DES treats a
/// missing sink the same way, without constructing records at all).
#[derive(Clone, Copy, Debug, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn record(&mut self, _t_ns: u64, _rec: TraceRecord) {}
}

/// Buffered JSONL sink over any writer. I/O errors are stashed and
/// surfaced by [`JsonlSink::finish`] so the hot recording path stays
/// infallible.
#[derive(Debug)]
pub struct JsonlSink<W: Write> {
    w: W,
    records: u64,
    err: Option<io::Error>,
}

impl JsonlSink<BufWriter<File>> {
    /// Open `path` for writing (truncating) behind a `BufWriter`.
    pub fn create<P: AsRef<Path>>(path: P) -> io::Result<JsonlSink<BufWriter<File>>> {
        Ok(JsonlSink::new(BufWriter::new(File::create(path)?)))
    }
}

impl<W: Write> JsonlSink<W> {
    pub fn new(w: W) -> JsonlSink<W> {
        JsonlSink { w, records: 0, err: None }
    }

    /// Records written so far.
    pub fn records(&self) -> u64 {
        self.records
    }

    /// Flush and return the inner writer, surfacing any stashed I/O
    /// error.
    pub fn finish(mut self) -> io::Result<W> {
        if let Some(e) = self.err.take() {
            return Err(e);
        }
        self.w.flush()?;
        Ok(self.w)
    }
}

impl<W: Write> TraceSink for JsonlSink<W> {
    fn record(&mut self, t_ns: u64, rec: TraceRecord) {
        if self.err.is_some() {
            return;
        }
        let mut line = rec.to_line(t_ns);
        line.push('\n');
        if let Err(e) = self.w.write_all(line.as_bytes()) {
            self.err = Some(e);
        } else {
            self.records += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lines_have_fixed_shape() {
        let r = TraceRecord::Dispatch {
            req: 7,
            hedge: true,
            why: DispatchWhy::Failover,
            device: -1,
            load: 3,
        };
        assert_eq!(
            r.to_line(1_000),
            r#"{"t":1000,"kind":"dispatch","req":7,"hedge":1,"why":"failover","device":-1,"load":3}"#
        );
        let d = TraceRecord::BatchDone {
            device: 0,
            size: 2,
            padding: 1,
            service_ns: 5,
            done: vec![9],
        };
        assert_eq!(
            d.to_line(0),
            r#"{"t":0,"kind":"batch_done","device":0,"size":2,"padding":1,"service_ns":5,"done":[9]}"#
        );
    }

    #[test]
    fn overload_lines_have_fixed_shape() {
        let r = TraceRecord::Reject { req: 42, class: 2, why: "queue" };
        assert_eq!(r.to_line(5), r#"{"t":5,"kind":"reject","req":42,"class":2,"why":"queue"}"#);
        let b = TraceRecord::BreakerTrip { device: 1, streak: 3 };
        assert_eq!(b.to_line(9), r#"{"t":9,"kind":"breaker_trip","device":1,"streak":3}"#);
        let e = TraceRecord::BrownoutEnter { attain_ppm: 812_500 };
        assert_eq!(e.to_line(0), r#"{"t":0,"kind":"brownout_enter","attain_ppm":812500}"#);
        let s = TraceRecord::OverloadSummary {
            rejected: 10,
            rejected_rate: 4,
            rejected_queue: 6,
            breaker_trips: 1,
            breaker_closes: 1,
            brownout_enters: 2,
            degraded_completions: 7,
        };
        assert_eq!(
            s.to_line(3),
            "{\"t\":3,\"kind\":\"overload_summary\",\"rejected\":10,\"rejected_rate\":4,\
             \"rejected_queue\":6,\"breaker_trips\":1,\"breaker_closes\":1,\
             \"brownout_enters\":2,\"degraded_completions\":7}"
        );
    }

    #[test]
    fn shard_lines_have_fixed_shape() {
        let r = TraceRecord::Route { req: 12, expert: 3, primary: 3, rerouted: false };
        assert_eq!(
            r.to_line(7),
            r#"{"t":7,"kind":"route","req":12,"expert":3,"primary":3,"rerouted":0}"#
        );
        // Expert-dropped requests route to -1.
        let d = TraceRecord::Route { req: 13, expert: -1, primary: 0, rerouted: false };
        assert_eq!(
            d.to_line(8),
            r#"{"t":8,"kind":"route","req":13,"expert":-1,"primary":0,"rerouted":0}"#
        );
        let x = TraceRecord::Xfer { req: 12, device: 1, remote: 2, xfer_ns: 500 };
        assert_eq!(
            x.to_line(9),
            r#"{"t":9,"kind":"xfer","req":12,"device":1,"remote":2,"xfer_ns":500}"#
        );
        let n = TraceRecord::NoReplica { req: 4, expert: 6 };
        assert_eq!(n.to_line(1), r#"{"t":1,"kind":"no_replica","req":4,"expert":6}"#);
        let a = TraceRecord::ReplicaAdd { expert: 6, device: 2 };
        assert_eq!(a.to_line(2), r#"{"t":2,"kind":"replica_add","expert":6,"device":2}"#);
        let p = TraceRecord::ReplicaDrop { expert: 6, device: 0 };
        assert_eq!(p.to_line(3), r#"{"t":3,"kind":"replica_drop","expert":6,"device":0}"#);
        let s = TraceRecord::ShardSummary {
            routed: 100,
            rerouted: 5,
            expert_drops: 2,
            no_replica: 1,
            transfers: 9,
            replica_adds: 3,
            replica_drops: 2,
        };
        assert_eq!(
            s.to_line(4),
            "{\"t\":4,\"kind\":\"shard_summary\",\"routed\":100,\"rerouted\":5,\
             \"expert_drops\":2,\"no_replica\":1,\"transfers\":9,\
             \"replica_adds\":3,\"replica_drops\":2}"
        );
    }

    #[test]
    fn jsonl_sink_buffers_lines() {
        let mut sink = JsonlSink::new(Vec::new());
        sink.record(1, TraceRecord::Flush { device: 0 });
        sink.record(2, TraceRecord::Retire { slot: 4 });
        assert_eq!(sink.records(), 2);
        let bytes = sink.finish().unwrap();
        let text = String::from_utf8(bytes).unwrap();
        assert_eq!(
            text,
            "{\"t\":1,\"kind\":\"flush\",\"device\":0}\n{\"t\":2,\"kind\":\"retire\",\"slot\":4}\n"
        );
        // NullSink accepts anything and keeps nothing.
        NullSink.record(0, TraceRecord::Flush { device: 0 });
    }
}
