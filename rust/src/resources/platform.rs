//! Platform envelopes: the FPGAs (and the comparison GPU) the paper
//! deploys on. Device figures are from the Xilinx data sheets; derating
//! ("usable" fractions) reflects that post-route designs cannot use
//! 100% of fabric — the paper's Table I sits at ~73% DSP on ZCU102.

use super::Resources;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlatformKind {
    Zcu102,
    AlveoU280,
    AlveoU250,
    TeslaV100S,
}

/// One deployment target.
#[derive(Clone, Debug)]
pub struct Platform {
    pub kind: PlatformKind,
    pub name: &'static str,
    /// Raw device resources (DSP48, BRAM18, LUT, FF).
    pub device: Resources,
    /// Fraction of each resource a router-friendly design may use.
    pub derate: f64,
    /// Achievable clock for this design family (MHz) — the paper closes
    /// timing at 300 (ZCU102), 200 (U280 W16A32) / 250 (U280 INT16).
    pub freq_mhz: f64,
    /// Off-chip bandwidth, GB/s (DDR4 or aggregate HBM).
    pub bw_gbs: f64,
    /// Independent memory channels (HBM pseudo-channels / DDR banks).
    pub mem_channels: usize,
    /// Super logic regions (dies). 1 for single-die.
    pub slrs: usize,
    /// Index of the SLR with direct memory attachment (U280: HBM on
    /// SLR0 — §III-A places the MoE block there).
    pub mem_slr: usize,
    /// Static + infrastructure power (W) when configured but idle.
    pub static_w: f64,
    /// Dynamic energy coefficients (calibrated; see sim/power.rs).
    pub dsp_mw_per_mhz: f64,
    pub bram_mw_per_mhz: f64,
    /// Per-active-channel memory subsystem power (W).
    pub chan_w: f64,
}

impl Platform {
    /// Budget available to the accelerator (post-derate). Routing
    /// pressure constrains DSP columns hardest; BRAM/LUT/FF derate
    /// more mildly (+0.13 — a post-route observation from the Table I
    /// designs).
    pub fn budget(&self) -> Resources {
        let mem_derate = (self.derate + 0.13).min(0.85);
        Resources {
            dsp: self.device.dsp * self.derate,
            bram18: self.device.bram18 * mem_derate,
            lut: self.device.lut * mem_derate,
            ff: self.device.ff * mem_derate,
        }
    }

    /// Bytes per cycle of off-chip bandwidth at this clock.
    pub fn bytes_per_cycle(&self) -> f64 {
        self.bw_gbs * 1e9 / (self.freq_mhz * 1e6)
    }

    pub fn cycles_to_ms(&self, cycles: f64) -> f64 {
        cycles / (self.freq_mhz * 1e6) * 1e3
    }

    /// Timing closure per activation bit-width (Table III): INT16
    /// designs close at 250 MHz on U280 instead of 200; other
    /// platforms keep their design-family clock. The single source of
    /// the rule — `report::deploy` and `serve::device::DeviceModel::
    /// from_search` must cost devices at the same frequency.
    pub fn with_bitwidth_timing(mut self, a_bits: u32) -> Platform {
        if a_bits <= 16 && self.kind == PlatformKind::AlveoU280 {
            self.freq_mhz = 250.0;
        }
        self
    }

    pub fn zcu102() -> Platform {
        Platform {
            kind: PlatformKind::Zcu102,
            name: "ZCU102",
            // XCZU9EG: 2520 DSP48E2, 912 BRAM36 = 1824 BRAM18,
            // 274k LUT, 548k FF.
            device: Resources { dsp: 2520.0, bram18: 1824.0, lut: 274_080.0, ff: 548_160.0 },
            derate: 0.75,
            freq_mhz: 300.0,
            bw_gbs: 19.2, // single DDR4-2400 x64
            mem_channels: 1,
            slrs: 1,
            mem_slr: 0,
            static_w: 2.8,
            dsp_mw_per_mhz: 0.008,
            bram_mw_per_mhz: 0.007,
            chan_w: 0.85,
        }
    }

    pub fn u280() -> Platform {
        Platform {
            kind: PlatformKind::AlveoU280,
            name: "Alveo U280",
            // XCU280: 9024 DSP48E2, 2016 BRAM36 = 4032 BRAM18 (+URAM,
            // not modeled separately), 1.3M LUT, 2.6M FF.
            device: Resources { dsp: 9024.0, bram18: 4032.0, lut: 1_303_680.0, ff: 2_607_360.0 },
            // Multi-die: SLR crossing, HBM infrastructure and the
            // host datapath (the paper cites exactly this for U280)
            // leave a much smaller routable fraction than single-die.
            derate: 0.42,
            freq_mhz: 200.0,
            bw_gbs: 460.0, // HBM2 32 pseudo-channels
            mem_channels: 32,
            slrs: 3,
            mem_slr: 0,
            static_w: 14.5,
            dsp_mw_per_mhz: 0.008,
            bram_mw_per_mhz: 0.007,
            chan_w: 0.2275,
        }
    }

    pub fn u250() -> Platform {
        Platform {
            kind: PlatformKind::AlveoU250,
            name: "Alveo U250",
            device: Resources { dsp: 12_288.0, bram18: 5_376.0, lut: 1_728_000.0, ff: 3_456_000.0 },
            derate: 0.50,
            freq_mhz: 300.0,
            bw_gbs: 77.0, // 4x DDR4-2400
            mem_channels: 4,
            slrs: 4,
            mem_slr: 0,
            static_w: 16.0,
            dsp_mw_per_mhz: 0.008,
            bram_mw_per_mhz: 0.007,
            chan_w: 1.1,
        }
    }

    /// Comparison GPU (Table II column 1). Resources are not meaningful
    /// for a GPU; only freq/BW/power fields are used by baselines/gpu.rs.
    pub fn v100s() -> Platform {
        Platform {
            kind: PlatformKind::TeslaV100S,
            name: "Tesla V100S",
            device: Resources { dsp: 0.0, bram18: 0.0, lut: 0.0, ff: 0.0 },
            derate: 1.0,
            freq_mhz: 1245.0,
            bw_gbs: 1134.0,
            mem_channels: 4,
            slrs: 1,
            mem_slr: 0,
            static_w: 39.0, // idle board power at batch-1 inference duty
            dsp_mw_per_mhz: 0.0,
            bram_mw_per_mhz: 0.0,
            chan_w: 0.0,
        }
    }

    pub fn by_name(name: &str) -> Option<Platform> {
        Some(match name.to_ascii_lowercase().as_str() {
            "zcu102" => Self::zcu102(),
            "u280" | "alveo-u280" => Self::u280(),
            "u250" | "alveo-u250" => Self::u250(),
            "v100s" | "gpu" => Self::v100s(),
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budgets_are_derated() {
        let z = Platform::zcu102();
        assert!(z.budget().dsp < z.device.dsp);
        // Paper Table I uses 1850 DSP on ZCU102 — must fit the budget.
        assert!(z.budget().dsp >= 1850.0, "budget {}", z.budget().dsp);
    }

    #[test]
    fn u280_budget_covers_table1() {
        let u = Platform::u280();
        let b = u.budget();
        // Table I: 3413 DSP, 974 BRAM(36 => 1948 BRAM18), 316.1K LUT.
        assert!(b.dsp >= 3413.0);
        assert!(b.bram18 >= 1948.0);
        assert!(b.lut >= 316_100.0);
    }

    #[test]
    fn bytes_per_cycle_sane() {
        let z = Platform::zcu102();
        // 19.2 GB/s at 300 MHz = 64 B/cycle.
        assert!((z.bytes_per_cycle() - 64.0).abs() < 1e-9);
    }

    #[test]
    fn cycles_to_ms() {
        let z = Platform::zcu102();
        assert!((z.cycles_to_ms(300_000.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Platform::by_name("zcu102").unwrap().kind, PlatformKind::Zcu102);
        assert_eq!(Platform::by_name("U280").unwrap().kind, PlatformKind::AlveoU280);
        assert!(Platform::by_name("zcu104").is_none());
    }

    #[test]
    fn bitwidth_timing_rule() {
        assert_eq!(Platform::u280().with_bitwidth_timing(16).freq_mhz, 250.0);
        assert_eq!(Platform::u280().with_bitwidth_timing(32).freq_mhz, 200.0);
        assert_eq!(Platform::zcu102().with_bitwidth_timing(16).freq_mhz, 300.0);
    }

    #[test]
    fn hbm_platform_has_many_channels() {
        assert!(Platform::u280().mem_channels > Platform::zcu102().mem_channels);
        assert_eq!(Platform::u280().slrs, 3);
    }
}
