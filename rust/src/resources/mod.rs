//! FPGA resource modeling: platform envelopes and the paper's analytic
//! DSP/BRAM models (§IV-A, Eq. 2–3), extended with LUT/FF estimators
//! calibrated against Table I so the Table I bench can report all four
//! columns.

pub mod platform;

pub use platform::{Platform, PlatformKind};

/// Ψ(q): DSP cost per MAC as a function of operand bit-width q (Eq. 2
/// narrative): one DSP48 handles a 16-bit MAC; two 8-bit MACs pack into
/// one DSP (WP486); ≤4-bit MACs are LUT-only.
pub fn psi(q_bits: u32) -> f64 {
    match q_bits {
        0..=4 => 0.0,
        5..=8 => 0.5,
        9..=16 => 1.0,
        17..=27 => 2.0, // wide multiplies split across DSP pairs
        _ => 4.0,       // 32-bit multiply: 4 DSP48 cascade
    }
}

/// DSP cost of one MAC lane at weight width `q_bits` and activation
/// width `a_bits`. Eq. 2's leading "2·Ψ(q)" is the W16**A32** case: a
/// 16×32 multiply spans a DSP pair (the paper's §V-B remark about "DSP
/// consumption in the 32-bit multiplication process" on U280). For A16
/// and below a single Ψ(q)-weighted DSP suffices — which is how the
/// INT16 designs of Table III fit twice the lanes.
pub fn mac_dsp_cost(q_bits: u32, a_bits: u32) -> f64 {
    let act_factor = if a_bits > 16 { 2.0 } else { 1.0 };
    act_factor * psi(q_bits)
}

/// DSPs consumed by one exponential unit (HLS expf: LUT table + mult
/// chain). Matches the D_exp term of Eq. 2.
pub const D_EXP: f64 = 5.0;

/// BRAM18s consumed by one exponential unit's tables (B_exp of Eq. 3).
pub const B_EXP: f64 = 2.0;

/// BRAM18 geometry used by Eq. 3.
pub const BRAM_WIDTH_BITS: u32 = 18;
pub const BRAM_DEPTH: u32 = 1024;

/// Resource usage of a kernel/block/design, in the paper's four
/// Table I columns. BRAM counted in 18Kb units.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Resources {
    pub dsp: f64,
    pub bram18: f64,
    pub lut: f64,
    pub ff: f64,
}

impl Resources {
    pub fn add(&self, o: &Resources) -> Resources {
        Resources {
            dsp: self.dsp + o.dsp,
            bram18: self.bram18 + o.bram18,
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
        }
    }

    pub fn scale(&self, k: f64) -> Resources {
        Resources {
            dsp: self.dsp * k,
            bram18: self.bram18 * k,
            lut: self.lut * k,
            ff: self.ff * k,
        }
    }

    /// Does this design fit within `budget` (all four columns)?
    pub fn fits(&self, budget: &Resources) -> bool {
        self.dsp <= budget.dsp
            && self.bram18 <= budget.bram18
            && self.lut <= budget.lut
            && self.ff <= budget.ff
    }

    /// Max utilization fraction across columns (for reports).
    pub fn max_util(&self, budget: &Resources) -> f64 {
        [
            self.dsp / budget.dsp,
            self.bram18 / budget.bram18,
            self.lut / budget.lut,
            self.ff / budget.ff,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }
}

/// Attention-kernel parameters appearing in Eq. 2–4.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AttnParams {
    /// T_a: tile width each PE multiplies per cycle.
    pub t_a: usize,
    /// N_a: number of attention PEs (each Q-stationary, Fig. 4b).
    pub n_a: usize,
}

/// Reusable-linear-kernel parameters (§III-C).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinearParams {
    /// T_in × T_out: the weight-tile (T_wt vector) MACs per CU per cycle.
    pub t_in: usize,
    pub t_out: usize,
    /// N_L: number of compute units behind the round-robin router.
    pub n_l: usize,
}

impl LinearParams {
    pub fn macs_per_cycle(&self) -> f64 {
        (self.t_in * self.t_out * self.n_l) as f64
    }
}

/// Eq. 2: D_attn = (2·Ψ(q)·T_a + D_exp·h)·N_a, with the leading 2
/// generalized to the activation-width factor (see [`mac_dsp_cost`]) —
/// for the paper's W16A32 designs this is Eq. 2 verbatim.
pub fn attn_dsp_w(p: &AttnParams, q_bits: u32, a_bits: u32, heads: usize) -> f64 {
    (mac_dsp_cost(q_bits, a_bits) * p.t_a as f64 + D_EXP * heads as f64) * p.n_a as f64
}

/// Eq. 2 exactly as printed (W16A32).
pub fn attn_dsp(p: &AttnParams, q_bits: u32, heads: usize) -> f64 {
    attn_dsp_w(p, q_bits, 32, heads)
}

/// Eq. 3: B_attn = 2·⌈q/bwidth⌉·⌈N/bdepth⌉ + B_exp·h·N_a.
pub fn attn_bram(p: &AttnParams, q_bits: u32, heads: usize, n_patches: usize) -> f64 {
    let word = (q_bits as f64 / BRAM_WIDTH_BITS as f64).ceil();
    let depth = (n_patches as f64 / BRAM_DEPTH as f64).ceil();
    2.0 * word * depth + B_EXP * heads as f64 * p.n_a as f64
}

/// DSPs of the reusable linear kernel: one MAC lane per element of the
/// T_in×T_out tile in each of the N_L CUs.
pub fn linear_dsp_w(p: &LinearParams, q_bits: u32, a_bits: u32) -> f64 {
    mac_dsp_cost(q_bits, a_bits) * (p.t_in * p.t_out * p.n_l) as f64
}

/// W16A32 variant (the paper's Table I/II designs).
pub fn linear_dsp(p: &LinearParams, q_bits: u32) -> f64 {
    linear_dsp_w(p, q_bits, 32)
}

/// BRAM of the reusable linear kernel: double-buffered weight tile per
/// CU plus the router's activation staging buffers. The weight tile is
/// banked by T_out (each output lane reads its own column every cycle),
/// so the tile costs max(T_out banks, capacity) BRAMs — ping-ponged.
pub fn linear_bram(p: &LinearParams, q_bits: u32, n_patches: usize, f_dim: usize) -> f64 {
    let tile_bits = (p.t_in * p.t_out) as f64 * q_bits as f64;
    let bram_bits = (BRAM_WIDTH_BITS * BRAM_DEPTH) as f64;
    let banks = (p.t_out as f64).max((tile_bits / bram_bits).ceil());
    let per_cu = 2.0 * banks; // ping-pong: stream next tile while computing
    // Router staging: one activation row buffer (f_dim) per CU + the
    // patch-index FIFO (depth N).
    let stage_bits = (f_dim * 32) as f64 + (n_patches * 16) as f64;
    let router = (stage_bits / bram_bits).ceil() * p.n_l as f64;
    per_cu * p.n_l as f64 + router
}

/// On-chip buffering beyond Eq. 3's per-kernel terms: the Fig. 3a
/// activation double buffers (Buf0/Buf1) and the K/V token buffers the
/// streaming attention kernel holds per head. Banked for parallel port
/// access (factor 1.4 — partial BRAMs left half-used by partitioning).
pub fn block_buffer_bram(n_patches: usize, f_dim: usize, a_bits: u32) -> f64 {
    let bram_bits = (BRAM_WIDTH_BITS * BRAM_DEPTH) as f64;
    let act_bits = (n_patches * f_dim * a_bits as usize) as f64;
    let banking = 1.4;
    // Buf0 + Buf1 (double buffer) + K + V on-chip.
    let bufs = 2.0 * (act_bits / bram_bits).ceil();
    let kv = 2.0 * (act_bits / bram_bits).ceil();
    banking * (bufs + kv)
}

/// LUT/FF estimators, linear in DSP/BRAM with a per-design base —
/// coefficients fit to Table I (two points per column family) plus HLS
/// rules of thumb. LUT/FF never constrain the paper's search (§IV-A
/// names DSP, RAM, BW as the limiting factors) so fidelity here only
/// affects the Table I report, not any decision.
pub fn estimate_lut_ff(dsp: f64, bram18: f64, streaming_modules: usize) -> (f64, f64) {
    let base_lut = 28_000.0; // host interface, control, AXI infrastructure
    let base_ff = 35_000.0;
    let lut = base_lut + 38.0 * dsp + 45.0 * bram18 + 2_200.0 * streaming_modules as f64;
    let ff = base_ff + 46.0 * dsp + 60.0 * bram18 + 2_600.0 * streaming_modules as f64;
    (lut, ff)
}

/// Full design usage from kernel params (attention + linear kernels +
/// `num` streaming linear modules in the MSA block).
pub fn design_resources(
    attn: &AttnParams,
    lin: &LinearParams,
    num_stream: usize,
    q_bits: u32,
    a_bits: u32,
    heads: usize,
    n_patches: usize,
    f_dim: usize,
) -> Resources {
    // Each streaming linear module in the MSA block is a T_a×N_a MAC
    // grid (same PE geometry as the attention kernel, so the GA can
    // trade them against each other) plus small stream FIFOs.
    let stream_dsp =
        mac_dsp_cost(q_bits, a_bits) * (attn.t_a * attn.n_a * num_stream) as f64;
    let stream_bram = 2.0 * num_stream as f64; // FIFO ping-pong pairs
    let dsp =
        attn_dsp_w(attn, q_bits, a_bits, heads) + linear_dsp_w(lin, q_bits, a_bits) + stream_dsp;
    let bram = attn_bram(attn, q_bits, heads, n_patches)
        + linear_bram(lin, q_bits, n_patches, f_dim)
        + stream_bram
        + block_buffer_bram(n_patches, f_dim, a_bits);
    let (lut, ff) = estimate_lut_ff(dsp, bram, num_stream);
    Resources { dsp, bram18: bram, lut, ff }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn psi_matches_paper_cases() {
        assert_eq!(psi(16), 1.0);
        assert_eq!(psi(12), 1.0);
        assert_eq!(psi(8), 0.5);
        assert_eq!(psi(5), 0.5);
        assert_eq!(psi(4), 0.0);
        assert_eq!(psi(2), 0.0);
        assert!(psi(32) > psi(16));
    }

    #[test]
    fn eq2_attn_dsp() {
        // (2·1·8 + 5·6)·4 = 184
        let p = AttnParams { t_a: 8, n_a: 4 };
        assert_eq!(attn_dsp(&p, 16, 6), 184.0);
        // A16: single-DSP lanes: (1·8 + 30)·4 = 152
        assert_eq!(attn_dsp_w(&p, 16, 16, 6), 152.0);
    }

    #[test]
    fn mac_cost_w16a32_is_two() {
        assert_eq!(mac_dsp_cost(16, 32), 2.0);
        assert_eq!(mac_dsp_cost(16, 16), 1.0);
        assert_eq!(mac_dsp_cost(8, 8), 0.5);
    }

    #[test]
    fn eq3_attn_bram() {
        // 2·⌈16/18⌉·⌈197/1024⌉ + 2·6·4 = 2 + 48
        let p = AttnParams { t_a: 8, n_a: 4 };
        assert_eq!(attn_bram(&p, 16, 6, 197), 50.0);
    }

    #[test]
    fn linear_dsp_scales_with_tile_and_cus() {
        let a = LinearParams { t_in: 4, t_out: 4, n_l: 2 };
        let b = LinearParams { t_in: 4, t_out: 4, n_l: 4 };
        assert_eq!(linear_dsp(&b, 16), 2.0 * linear_dsp(&a, 16));
        assert_eq!(linear_dsp(&a, 8), 0.5 * linear_dsp(&a, 16));
    }

    #[test]
    fn fits_and_util() {
        let budget = Resources { dsp: 100.0, bram18: 100.0, lut: 1e5, ff: 1e5 };
        let use_ = Resources { dsp: 50.0, bram18: 80.0, lut: 5e4, ff: 5e4 };
        assert!(use_.fits(&budget));
        assert!((use_.max_util(&budget) - 0.8).abs() < 1e-12);
        let over = Resources { dsp: 101.0, ..use_ };
        assert!(!over.fits(&budget));
    }

    #[test]
    fn design_resources_monotone_in_parallelism() {
        let lin = LinearParams { t_in: 8, t_out: 8, n_l: 2 };
        let small =
            design_resources(&AttnParams { t_a: 4, n_a: 2 }, &lin, 1, 16, 32, 6, 197, 384);
        let big =
            design_resources(&AttnParams { t_a: 8, n_a: 4 }, &lin, 2, 16, 32, 6, 197, 384);
        assert!(big.dsp > small.dsp);
        assert!(big.bram18 >= small.bram18);
        assert!(big.lut > small.lut);
        assert!(big.ff > small.ff);
    }

    #[test]
    fn bram_counts_double_buffered_weight_tiles() {
        let small = LinearParams { t_in: 8, t_out: 8, n_l: 1 };
        let big = LinearParams { t_in: 32, t_out: 32, n_l: 1 };
        assert!(
            linear_bram(&big, 16, 197, 384) > linear_bram(&small, 16, 197, 384),
            "bigger weight tile must cost more BRAM"
        );
    }
}
