//! Workload descriptions: the models the paper evaluates, as shape/op
//! metadata consumed by the simulator, the HAS search and the report
//! layer. Mirrors `python/compile/configs.py` (which owns the shapes
//! used to author the actual JAX computation); `tests/` cross-check the
//! two through artifact metadata.

pub mod ops;

/// A MoE-ViT / ViT / BERT-style encoder stack, described by shape.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    /// Embedding dim F (the paper's feature dimension 𝓕).
    pub dim: usize,
    pub heads: usize,
    pub depth: usize,
    /// Token count N (image patches + cls, or sequence length).
    pub patches: usize,
    /// Dense FFN hidden = mlp_ratio * dim.
    pub mlp_ratio: usize,
    /// Number of experts E; 0 => plain transformer, no MoE layers.
    pub num_experts: usize,
    /// Experts activated per token.
    pub top_k: usize,
    /// Expert MLP hidden dim (0 => mlp_ratio * dim).
    pub expert_hidden: usize,
    /// MoE block replaces the FFN in every `moe_every`-th encoder
    /// (odd layer indices, matching M3ViT "every alternate encoder").
    pub moe_every: usize,
    pub img_size: usize,
    pub patch_size: usize,
    pub in_chans: usize,
    pub num_classes: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.dim % self.heads, 0);
        self.dim / self.heads
    }

    pub fn expert_dim(&self) -> usize {
        if self.expert_hidden != 0 {
            self.expert_hidden
        } else {
            self.dim * self.mlp_ratio
        }
    }

    pub fn is_moe_layer(&self, i: usize) -> bool {
        self.num_experts > 0 && i % self.moe_every == 1
    }

    pub fn moe_layers(&self) -> Vec<usize> {
        (0..self.depth).filter(|&i| self.is_moe_layer(i)).collect()
    }

    pub fn num_moe_layers(&self) -> usize {
        self.moe_layers().len()
    }
}

/// M3ViT as deployed in Table II: ViT-small backbone, 16 experts,
/// top-2, MoE in alternate encoders.
pub fn m3vit_small() -> ModelConfig {
    ModelConfig {
        name: "m3vit-small",
        dim: 384,
        heads: 6,
        depth: 12,
        patches: 197,
        mlp_ratio: 4,
        num_experts: 16,
        top_k: 2,
        expert_hidden: 0,
        moe_every: 2,
        img_size: 224,
        patch_size: 16,
        in_chans: 3,
        num_classes: 1000,
    }
}

/// ViT-Tiny (Table III, UbiMoE-E row).
pub fn vit_t() -> ModelConfig {
    ModelConfig {
        name: "vit-t",
        dim: 192,
        heads: 3,
        depth: 12,
        patches: 197,
        mlp_ratio: 4,
        num_experts: 0,
        top_k: 0,
        expert_hidden: 0,
        moe_every: 2,
        img_size: 224,
        patch_size: 16,
        in_chans: 3,
        num_classes: 1000,
    }
}

/// ViT-Small (Table III, UbiMoE-C row).
pub fn vit_s() -> ModelConfig {
    ModelConfig { name: "vit-s", num_experts: 0, top_k: 0, ..m3vit_small() }
}

/// DeiT-S — same shape as ViT-S (HeatViT's model, Table III context).
pub fn deit_s() -> ModelConfig {
    ModelConfig { name: "deit-s", ..vit_s() }
}

/// BERT-Base over a 128-token sequence (TECS'23's model, Table III
/// context). Encoder structure is identical to ViT for our purposes.
pub fn bert_b() -> ModelConfig {
    ModelConfig {
        name: "bert-b",
        dim: 768,
        heads: 12,
        depth: 12,
        patches: 128,
        mlp_ratio: 4,
        num_experts: 0,
        top_k: 0,
        expert_hidden: 0,
        moe_every: 2,
        img_size: 0,
        patch_size: 1,
        in_chans: 0,
        num_classes: 2,
    }
}

/// The end-to-end driver model (matches python m3vit-tiny: the AOT
/// artifacts the Rust runtime actually executes).
pub fn m3vit_tiny() -> ModelConfig {
    ModelConfig {
        name: "m3vit-tiny",
        dim: 192,
        heads: 3,
        depth: 6,
        patches: 65,
        mlp_ratio: 4,
        num_experts: 8,
        top_k: 2,
        expert_hidden: 0,
        moe_every: 2,
        img_size: 64,
        patch_size: 8,
        in_chans: 3,
        num_classes: 10,
    }
}

/// Tiny config used by pytest (kept here so metadata cross-checks can
/// resolve it too).
pub fn m3vit_micro() -> ModelConfig {
    ModelConfig {
        name: "m3vit-micro",
        dim: 32,
        heads: 2,
        depth: 2,
        patches: 17,
        mlp_ratio: 4,
        num_experts: 4,
        top_k: 2,
        expert_hidden: 64,
        moe_every: 2,
        img_size: 16,
        patch_size: 4,
        in_chans: 3,
        num_classes: 10,
    }
}

pub fn by_name(name: &str) -> Option<ModelConfig> {
    Some(match name {
        "m3vit-small" => m3vit_small(),
        "m3vit-tiny" => m3vit_tiny(),
        "m3vit-micro" => m3vit_micro(),
        "vit-t" => vit_t(),
        "vit-s" => vit_s(),
        "deit-s" => deit_s(),
        "bert-b" => bert_b(),
        _ => return None,
    })
}

pub fn all_names() -> &'static [&'static str] {
    &["m3vit-small", "m3vit-tiny", "m3vit-micro", "vit-t", "vit-s", "deit-s", "bert-b"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_internally_consistent() {
        for name in all_names() {
            let c = by_name(name).unwrap();
            assert_eq!(c.name, *name);
            assert_eq!(c.dim % c.heads, 0, "{name}");
            if c.img_size > 0 {
                let n = (c.img_size / c.patch_size).pow(2) + 1;
                assert_eq!(c.patches, n, "{name}");
            }
        }
    }

    #[test]
    fn moe_layer_placement_matches_m3vit() {
        let c = m3vit_small();
        assert_eq!(c.moe_layers(), vec![1, 3, 5, 7, 9, 11]);
        assert_eq!(m3vit_tiny().moe_layers(), vec![1, 3, 5]);
        assert!(vit_s().moe_layers().is_empty());
    }

    #[test]
    fn by_name_rejects_unknown() {
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn expert_dim_default_and_override() {
        assert_eq!(m3vit_small().expert_dim(), 1536);
        assert_eq!(m3vit_micro().expert_dim(), 64);
    }
}
