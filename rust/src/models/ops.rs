//! Analytical workload accounting: MACs, ops (2·MAC), weight bytes and
//! activation bytes per block. The simulator (sim/), the HAS search
//! (has/) and every baseline model consume these numbers, so keeping
//! them in one audited place is what makes the reproduced tables
//! internally consistent.
//!
//! Convention: `ops = 2 * MACs` (multiply + add), the usual GOPS
//! convention in the FPGA accelerator literature. The paper's Table II
//! implies a smaller per-inference op count (~2.2–2.5 GOP) than our
//! analytical count for a ViT-S-backbone M3ViT (11.88 GOP); see
//! EXPERIMENTS.md §Op-count convention. Every system in a table runs
//! the same workload here, so ratios are convention-independent.

use super::ModelConfig;

/// MAC / byte accounting for one block instance.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BlockOps {
    pub macs: u64,
    /// Parameter bytes that must be streamed from off-chip (per pass).
    pub weight_bytes: u64,
    /// Activation bytes read + written (DDR traffic under the Fig. 3
    /// host-managed double-buffer flow).
    pub act_bytes: u64,
}

impl BlockOps {
    pub fn ops(&self) -> u64 {
        2 * self.macs
    }

    pub fn add(&self, other: &BlockOps) -> BlockOps {
        BlockOps {
            macs: self.macs + other.macs,
            weight_bytes: self.weight_bytes + other.weight_bytes,
            act_bytes: self.act_bytes + other.act_bytes,
        }
    }

    pub fn scale(&self, k: u64) -> BlockOps {
        BlockOps {
            macs: self.macs * k,
            weight_bytes: self.weight_bytes * k,
            act_bytes: self.act_bytes * k,
        }
    }
}

/// Bytes per weight element at bit-width `q_bits` (paper: W16 ⇒ 2).
fn wbytes(q_bits: u32) -> u64 {
    (q_bits as u64).div_ceil(8)
}

/// Bytes per activation element (paper: A32 ⇒ 4).
fn abytes(a_bits: u32) -> u64 {
    (a_bits as u64).div_ceil(8)
}

/// MSA block: QKV generation + attention (QK^T and PV) + projection.
pub fn msa_ops(c: &ModelConfig, q_bits: u32, a_bits: u32) -> BlockOps {
    let (n, f) = (c.patches as u64, c.dim as u64);
    let qkv = n * f * 3 * f;
    let attn = 2 * n * n * f; // h * (N² d) for QK^T plus same for P·V
    let proj = n * f * f;
    BlockOps {
        macs: qkv + attn + proj,
        weight_bytes: (3 * f * f + f * f) * wbytes(q_bits),
        act_bytes: 2 * n * f * abytes(a_bits), // read x, write y
    }
}

/// Dense FFN block: two linears with hidden = mlp_ratio · F.
pub fn ffn_ops(c: &ModelConfig, q_bits: u32, a_bits: u32) -> BlockOps {
    let (n, f) = (c.patches as u64, c.dim as u64);
    let h = (c.mlp_ratio * c.dim) as u64;
    BlockOps {
        macs: n * 2 * f * h,
        weight_bytes: 2 * f * h * wbytes(q_bits),
        act_bytes: 2 * n * f * abytes(a_bits),
    }
}

/// MoE block: gate + top-k expert FFNs per token, expert-by-expert.
/// Weight traffic covers **all E experts** (each is streamed in once
/// per block — M3ViT's computation order), while compute covers only
/// the top-k activated paths.
pub fn moe_ops(c: &ModelConfig, q_bits: u32, a_bits: u32) -> BlockOps {
    let (n, f) = (c.patches as u64, c.dim as u64);
    let (e, k, d) = (c.num_experts as u64, c.top_k as u64, c.expert_dim() as u64);
    let gate = n * f * e;
    let experts = k * n * 2 * f * d;
    BlockOps {
        macs: gate + experts,
        weight_bytes: (f * e + e * 2 * f * d) * wbytes(q_bits),
        act_bytes: 2 * n * f * abytes(a_bits),
    }
}

/// Patch embedding (conv-as-linear) + cls/pos add.
pub fn embed_ops(c: &ModelConfig, q_bits: u32, a_bits: u32) -> BlockOps {
    if c.img_size == 0 {
        return BlockOps::default(); // sequence models: embedding lookup only
    }
    let n = (c.patches - 1) as u64;
    let pin = (c.in_chans * c.patch_size * c.patch_size) as u64;
    let f = c.dim as u64;
    BlockOps {
        macs: n * pin * f,
        weight_bytes: pin * f * wbytes(q_bits),
        act_bytes: (n * pin + c.patches as u64 * f) * abytes(a_bits),
    }
}

/// Classifier head (cls token only).
pub fn head_ops(c: &ModelConfig, q_bits: u32, a_bits: u32) -> BlockOps {
    let f = c.dim as u64;
    let cls = c.num_classes as u64;
    BlockOps {
        macs: f * cls,
        weight_bytes: f * cls * wbytes(q_bits),
        act_bytes: (f + cls) * abytes(a_bits),
    }
}

/// Full-model accounting at batch 1.
#[derive(Clone, Debug)]
pub struct ModelOps {
    pub per_layer_msa: BlockOps,
    pub per_layer_ffn: BlockOps,
    pub per_layer_moe: BlockOps,
    pub embed: BlockOps,
    pub head: BlockOps,
    pub num_ffn_layers: u64,
    pub num_moe_layers: u64,
    pub depth: u64,
}

impl ModelOps {
    pub fn total(&self) -> BlockOps {
        self.embed
            .add(&self.head)
            .add(&self.per_layer_msa.scale(self.depth))
            .add(&self.per_layer_ffn.scale(self.num_ffn_layers))
            .add(&self.per_layer_moe.scale(self.num_moe_layers))
    }

    pub fn total_gop(&self) -> f64 {
        self.total().ops() as f64 / 1e9
    }
}

/// Compute the full accounting for a model at given bit-widths.
pub fn model_ops(c: &ModelConfig, q_bits: u32, a_bits: u32) -> ModelOps {
    let n_moe = c.num_moe_layers() as u64;
    ModelOps {
        per_layer_msa: msa_ops(c, q_bits, a_bits),
        per_layer_ffn: ffn_ops(c, q_bits, a_bits),
        per_layer_moe: moe_ops(c, q_bits, a_bits),
        embed: embed_ops(c, q_bits, a_bits),
        head: head_ops(c, q_bits, a_bits),
        num_ffn_layers: c.depth as u64 - n_moe,
        num_moe_layers: n_moe,
        depth: c.depth as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{bert_b, m3vit_small, m3vit_tiny, vit_s, vit_t};

    #[test]
    fn m3vit_small_total_matches_python_pin() {
        // Must equal the value python/tests/test_model.py pins (11.88).
        let ops = model_ops(&m3vit_small(), 16, 32);
        let encoder_only = ops
            .per_layer_msa
            .scale(12)
            .add(&ops.per_layer_ffn.scale(6))
            .add(&ops.per_layer_moe.scale(6));
        let gop = encoder_only.ops() as f64 / 1e9;
        assert!((gop - 11.884603392).abs() < 1e-6, "{gop}");
    }

    #[test]
    fn vit_s_larger_than_vit_t() {
        let s = model_ops(&vit_s(), 16, 32).total_gop();
        let t = model_ops(&vit_t(), 16, 32).total_gop();
        assert!(s > 3.0 * t, "s={s} t={t}"); // dim 2x => ~4x linear work
    }

    #[test]
    fn moe_weight_traffic_covers_all_experts() {
        let c = m3vit_small();
        let moe = moe_ops(&c, 16, 32);
        let per_expert = 2 * (c.dim * c.expert_dim()) as u64 * 2; // W16 = 2B
        assert!(moe.weight_bytes >= c.num_experts as u64 * per_expert);
    }

    #[test]
    fn moe_compute_covers_topk_only() {
        let c = m3vit_small();
        let moe = moe_ops(&c, 16, 32);
        let full = c.num_experts as u64
            * (c.top_k as u64 / c.top_k as u64)
            * (c.patches * 2 * c.dim * c.expert_dim()) as u64;
        assert!(moe.macs < full / 4, "sparse activation must be reflected");
    }

    #[test]
    fn bert_has_no_patch_embed() {
        let ops = model_ops(&bert_b(), 8, 8);
        assert_eq!(ops.embed, BlockOps::default());
        assert!(ops.total_gop() > 10.0); // BERT-base @128 tokens ≈ 22 GOP
    }

    #[test]
    fn tiny_is_much_smaller_than_small() {
        let t = model_ops(&m3vit_tiny(), 16, 32).total_gop();
        let s = model_ops(&m3vit_small(), 16, 32).total_gop();
        assert!(t < s / 10.0, "t={t} s={s}");
    }

    #[test]
    fn ops_is_twice_macs() {
        let b = BlockOps { macs: 21, weight_bytes: 0, act_bytes: 0 };
        assert_eq!(b.ops(), 42);
    }

    #[test]
    fn bitwidth_scales_weight_bytes() {
        let c = vit_s();
        let w16 = msa_ops(&c, 16, 32).weight_bytes;
        let w8 = msa_ops(&c, 8, 32).weight_bytes;
        assert_eq!(w16, 2 * w8);
    }
}
