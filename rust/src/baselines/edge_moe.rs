//! Edge-MoE baseline (Sarkar et al., ICCAD'23): the prior-SOTA M3ViT
//! accelerator on ZCU102 that UbiMoE compares against in Table II.
//!
//! Architectural differences captured by the model (from the Edge-MoE
//! paper + UbiMoE's §I critique):
//!  1. a single *unified reusable* compute engine time-shared by all
//!     operators (no independent MSA/MoE blocks) ⇒ no Fig. 3 overlap:
//!     block latencies add instead of max;
//!  2. attention is computed by the shared engine with a non-fused
//!     safe softmax (separate max / exp-sum / divide passes over the
//!     score matrix) ⇒ extra passes and score-buffer round trips;
//!  3. the same expert-by-expert weight streaming M3ViT prescribes
//!     (that part Edge-MoE did optimize, and we credit it).

use crate::baselines::PerfPoint;
use crate::models::{ops, ModelConfig};
use crate::resources::{LinearParams, Platform, Resources};
use crate::sim::linear::{compute_cycles, LinearTask};
use crate::sim::memory::{share_transfer_cycles, BwAllocation, MemorySystem};
use crate::sim::moe::GateHistogram;
use crate::sim::power::design_power;

/// Edge-MoE's published ZCU102 configuration footprint (ICCAD'23):
/// ~1858 DSP, ~1088 BRAM18-equivalents — same device class as UbiMoE's
/// Table I row, which is what makes the comparison fair.
fn edge_moe_resources() -> Resources {
    Resources { dsp: 1858.0, bram18: 1088.0, lut: 153_000.0, ff: 188_000.0 }
}

/// The shared engine: one big reusable MAC array. At W16A32 and the
/// published DSP count, the lane budget is DSP/2 (one 16×32 MAC spans a
/// DSP pair), organized as an adaptable tile.
fn shared_engine() -> LinearParams {
    // ~1700 usable MAC DSPs / 2 = 850 lanes ≈ 16×16×3
    LinearParams { t_in: 16, t_out: 16, n_l: 3 }
}

/// Extra passes the non-fused safe softmax costs on the shared engine:
/// pass 1 computes scores + max, pass 2 exp + sum (re-reading scores),
/// pass 3 divide + ·V. The fused UbiMoE kernel does all of it in one.
const SOFTMAX_PASSES: f64 = 3.0;

/// Short-row utilization of the shared engine on attention matmuls:
/// per-head d=64 tiles map poorly onto a kernel shaped for F×4F FFN
/// GEMMs (the §I critique: "only emphasizes reusable computational
/// kernels, overlooking latency optimization for critical
/// bottlenecks").
const ATTN_UTILIZATION: f64 = 0.35;

/// Operator-granularity intermediate spills: a single time-shared
/// engine computes op-by-op, writing each intermediate back to DDR and
/// re-reading it (UbiMoE streams producer→consumer on-chip). Ops per
/// MSA block that round-trip their N×F activation.
const MSA_SPILL_OPS: f64 = 5.0;
const FFN_SPILL_OPS: f64 = 2.0;

pub fn simulate_edge_moe(model: &ModelConfig) -> PerfPoint {
    let plat = Platform::zcu102();
    let mem = MemorySystem::new(plat.mem_channels, plat.bw_gbs, plat.freq_mhz);
    let bw = BwAllocation::for_channels(plat.mem_channels);
    let lin = shared_engine();
    let c = model;
    let (n, f) = (c.patches, c.dim);
    let qb = 2u64; // W16

    let mut cycles = 0.0;

    // Patch embed.
    if c.img_size > 0 {
        let pin = c.in_chans * c.patch_size * c.patch_size;
        let t = LinearTask {
            tokens: n - 1,
            f_in: pin,
            f_out: f,
            weight_bytes: (pin * f) as u64 * qb,
        };
        cycles += crate::sim::linear::task_cycles(&t, &lin, &mem, bw.moe_weights);
    }

    for i in 0..c.depth {
        // --- MSA on the shared engine (sequential stages).
        let qkv = LinearTask {
            tokens: n,
            f_in: f,
            f_out: 3 * f,
            weight_bytes: (3 * f * f) as u64 * qb,
        };
        let proj =
            LinearTask { tokens: n, f_in: f, f_out: f, weight_bytes: (f * f) as u64 * qb };
        cycles += crate::sim::linear::task_cycles(&qkv, &lin, &mem, bw.msa);
        // Attention as two big matmuls + the multi-pass softmax, at
        // the shared engine's poor short-row utilization.
        let qk = LinearTask { tokens: n, f_in: f, f_out: n, weight_bytes: 0 };
        let pv = LinearTask { tokens: n, f_in: n, f_out: f, weight_bytes: 0 };
        let attn_mm =
            (compute_cycles(&qk, &lin) + compute_cycles(&pv, &lin)) / ATTN_UTILIZATION;
        // softmax passes stream the h·N² score matrix SOFTMAX_PASSES×
        // through the engine at one element/lane/cycle plus a DDR
        // round trip for the score buffer (does not fit on-chip at
        // N=197, h=6 with everything else resident).
        let score_elems = (c.heads * n * n) as f64;
        let softmax = SOFTMAX_PASSES * score_elems / lin.macs_per_cycle().sqrt()
            + 2.0 * share_transfer_cycles(&mem, (score_elems as u64) * 4, bw.msa);
        cycles += attn_mm + softmax;
        cycles += crate::sim::linear::task_cycles(&proj, &lin, &mem, bw.msa);
        // Operator-granularity activation spills (rd + wr per op).
        let act_bytes = (n * f * 4) as u64;
        let spill_ops =
            MSA_SPILL_OPS + if c.is_moe_layer(i) { FFN_SPILL_OPS + 1.0 } else { FFN_SPILL_OPS };
        cycles += spill_ops
            * 2.0
            * share_transfer_cycles(&mem, act_bytes, bw.msa + bw.activations);

        // --- FFN / MoE on the same engine (no overlap possible).
        if c.is_moe_layer(i) {
            let h = GateHistogram::balanced(c);
            cycles +=
                crate::sim::moe::moe_block_cycles(c, &h, &lin, &mem, bw.moe_weights);
        } else {
            cycles += crate::sim::moe::ffn_block_cycles(c, &lin, &mem, bw.moe_weights);
        }
    }

    // Head.
    let head = LinearTask {
        tokens: 1,
        f_in: f,
        f_out: c.num_classes,
        weight_bytes: (f * c.num_classes) as u64 * qb,
    };
    cycles += crate::sim::linear::task_cycles(&head, &lin, &mem, bw.moe_weights);

    let latency_ms = plat.cycles_to_ms(cycles);
    let acc = ops::model_ops(c, 16, 32);
    let gops = acc.total_gop() / (latency_ms / 1e3);
    let power_w = design_power(&plat, &edge_moe_resources(), 1);
    PerfPoint {
        system: "Edge-MoE".into(),
        platform: plat.name.into(),
        bitwidth: "W16A32".into(),
        freq_mhz: plat.freq_mhz,
        power_w,
        latency_ms,
        gops,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::m3vit_small;

    #[test]
    fn latency_in_paper_ballpark() {
        // Paper Table II: 34.64 ms on the 2.5-GOP convention; on our
        // 11.9-GOP accounting the absolute value scales ~4.75× but must
        // stay within the same class (tens of ms, slower than UbiMoE —
        // checked in report/ tests).
        let p = simulate_edge_moe(&m3vit_small());
        assert!(p.latency_ms > 10.0 && p.latency_ms < 500.0, "{}", p.latency_ms);
    }

    #[test]
    fn power_near_paper_value() {
        // Paper: 14.54 W for Edge-MoE on ZCU102.
        let p = simulate_edge_moe(&m3vit_small());
        assert!((p.power_w - 14.54).abs() / 14.54 < 0.25, "{:.2} W", p.power_w);
    }

    #[test]
    fn runs_at_300mhz_w16a32() {
        let p = simulate_edge_moe(&m3vit_small());
        assert_eq!(p.freq_mhz, 300.0);
        assert_eq!(p.bitwidth, "W16A32");
    }
}
