//! Comparator systems for Tables II–III: the V100S GPU (PyTorch
//! batch-1), Edge-MoE (the prior-SOTA M3ViT accelerator), and the
//! published HeatViT / TECS'23 rows.
//!
//! Each baseline reports the same [`PerfPoint`] the UbiMoE simulator
//! reports, over the same workload accounting (models/ops.rs), so
//! within-table ratios are convention-independent.

pub mod edge_moe;
pub mod gpu;
pub mod published;

/// One row of a comparison table.
#[derive(Clone, Debug)]
pub struct PerfPoint {
    pub system: String,
    pub platform: String,
    pub bitwidth: String,
    pub freq_mhz: f64,
    pub power_w: f64,
    pub latency_ms: f64,
    pub gops: f64,
}

impl PerfPoint {
    pub fn gops_per_w(&self) -> f64 {
        self.gops / self.power_w
    }

    /// Throughput speedup of `self` over `other`.
    pub fn speedup_over(&self, other: &PerfPoint) -> f64 {
        self.gops / other.gops
    }

    /// Efficiency improvement of `self` over `other`.
    pub fn efficiency_gain_over(&self, other: &PerfPoint) -> f64 {
        self.gops_per_w() / other.gops_per_w()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(gops: f64, w: f64) -> PerfPoint {
        PerfPoint {
            system: "x".into(),
            platform: "p".into(),
            bitwidth: "W16A32".into(),
            freq_mhz: 300.0,
            power_w: w,
            latency_ms: 1.0,
            gops,
        }
    }

    #[test]
    fn ratio_math() {
        let a = point(100.0, 10.0);
        let b = point(50.0, 10.0);
        assert!((a.speedup_over(&b) - 2.0).abs() < 1e-12);
        assert!((a.efficiency_gain_over(&b) - 2.0).abs() < 1e-12);
    }
}
