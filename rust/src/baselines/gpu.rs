//! GPU baseline: Tesla V100S running M3ViT under PyTorch at batch 1
//! (Table II column 1).
//!
//! Batch-1 transformer inference on a datacenter GPU is dominated by
//! kernel-launch/framework overhead and low-occupancy kernels, not by
//! peak FLOPs — which is how a 16-TFLOP part ends up at ~55 GOPS. We
//! model it as: per-op launch overhead + compute at a size-dependent
//! achievable fraction of peak + weight traffic at HBM bandwidth. The
//! overhead constant is calibrated once against the paper's measured
//! 40.1 ms (EXPERIMENTS.md §Calibration).

use crate::baselines::PerfPoint;
use crate::models::{ops, ModelConfig};
use crate::resources::Platform;

/// V100S fp32 peak (no tensor cores for fp32 PyTorch eager): 16.4 TFLOPs.
const PEAK_FLOPS: f64 = 16.4e12;
/// Measured-ish per-kernel launch + framework dispatch cost (PyTorch
/// eager, CUDA 11): calibrated to the paper's latency.
const LAUNCH_OVERHEAD_S: f64 = 100e-6;
/// Batch-1 matmul occupancy on V100S (tall-skinny GEMMs).
fn achievable_fraction(macs: u64) -> f64 {
    // Tiny GEMMs can't fill 80 SMs; scale from 2% to 35% with size.
    let x = macs as f64;
    (0.02 + 0.33 * (x / (x + 5e8))).min(0.35)
}

/// Count of CUDA kernel launches per block (PyTorch eager: each linear,
/// layernorm, softmax, residual add, transpose... is a launch).
fn launches_per_layer(c: &ModelConfig, moe: bool) -> f64 {
    let msa = 12.0; // ln, qkv, split, 2 bmm, softmax(3), proj, add, reshapes
    if moe {
        // gate (linear+topk+softmax) + per-expert gather/2×linear/gelu/scatter
        msa + 4.0 + c.num_experts as f64 * 5.0
    } else {
        msa + 5.0 // ln, fc1, gelu, fc2, add
    }
}

/// Simulate the GPU point for `model`.
pub fn simulate_gpu(model: &ModelConfig) -> PerfPoint {
    let plat = Platform::v100s();
    let acc = ops::model_ops(model, 32, 32); // fp32 weights on GPU
    let mut seconds = 0.0;

    let mut add_block = |blk: &ops::BlockOps, launches: f64, count: f64| {
        let flops = blk.ops() as f64;
        let compute = flops / (PEAK_FLOPS * achievable_fraction(blk.macs));
        // fp32 weights must be read from HBM once per pass.
        let mem = blk.weight_bytes as f64 * 2.0 / (plat.bw_gbs * 1e9); // W16→fp32: ×2
        seconds += count * (launches * LAUNCH_OVERHEAD_S + compute.max(mem));
    };

    add_block(&acc.per_layer_msa, launches_per_layer(model, false) - 5.0, acc.depth as f64);
    add_block(&acc.per_layer_ffn, 5.0, acc.num_ffn_layers as f64);
    add_block(
        &acc.per_layer_moe,
        launches_per_layer(model, true) - 12.0,
        acc.num_moe_layers as f64,
    );
    add_block(&acc.embed, 3.0, 1.0);
    add_block(&acc.head, 2.0, 1.0);

    let latency_ms = seconds * 1e3;
    let gop = acc.total_gop();
    // Paper measures 51 W board power at this duty cycle.
    let power_w = 51.0;
    PerfPoint {
        system: "GPU (PyTorch)".into(),
        platform: plat.name.into(),
        bitwidth: "FP32".into(),
        freq_mhz: plat.freq_mhz,
        power_w,
        latency_ms,
        gops: gop / (latency_ms / 1e3),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{m3vit_small, vit_s};

    #[test]
    fn m3vit_latency_order_of_paper() {
        // Paper: 40.1 ms. Our whole latency scale is inflated ~1.35×
        // by the op-count convention (see EXPERIMENTS.md), so the GPU
        // model is calibrated to preserve the *ratios* against the
        // FPGA points — it must land in the same class (35–70 ms),
        // not on the paper's absolute number.
        let p = simulate_gpu(&m3vit_small());
        assert!(
            p.latency_ms > 35.0 && p.latency_ms < 95.0,
            "GPU latency {:.1} ms out of class",
            p.latency_ms
        );
    }

    #[test]
    fn gpu_efficiency_is_poor() {
        // Paper: 1.075 GOPS/W — the FPGA designs beat it by ~8x. With
        // our op convention GOPS is scaled by the same factor for every
        // system; absolute GOPS/W here lands higher, but must stay far
        // below any FPGA point (cross-checked in report tests).
        let p = simulate_gpu(&m3vit_small());
        assert!(p.power_w >= 50.0);
        assert!(p.gops > 0.0);
    }

    #[test]
    fn moe_dominates_gpu_latency() {
        // The expert loop's launch storm is the GPU's pain point — the
        // motivation for accelerators in the first place.
        let moe = simulate_gpu(&m3vit_small());
        let dense = simulate_gpu(&vit_s());
        assert!(moe.latency_ms > dense.latency_ms * 1.5);
    }

    #[test]
    fn achievable_fraction_bounded() {
        assert!(achievable_fraction(1) >= 0.02);
        assert!(achievable_fraction(u64::MAX / 2) <= 0.35);
    }
}
