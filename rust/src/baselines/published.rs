//! Published comparator rows (Table III context): HeatViT (HPCA'23)
//! and the TECS'23 reconfigurable systolic attention accelerator. These
//! systems were never re-run by the UbiMoE authors either — Table III
//! quotes their published numbers; we do the same, as data.

use crate::baselines::PerfPoint;

/// HeatViT on ZCU102, DeiT-S, INT8 (Table III column 1).
pub fn heatvit() -> PerfPoint {
    PerfPoint {
        system: "HeatViT".into(),
        platform: "ZCU102".into(),
        bitwidth: "INT8".into(),
        freq_mhz: 300.0,
        power_w: 10.697,
        latency_ms: 9.15,
        gops: 220.6,
    }
}

/// TECS'23 on U250, BERT-Base, INT8 (Table III column 3).
pub fn tecs23() -> PerfPoint {
    PerfPoint {
        system: "TECS'23".into(),
        platform: "U250".into(),
        bitwidth: "INT8".into(),
        freq_mhz: 300.0,
        power_w: 77.168,
        latency_ms: f64::NAN, // not reported in the paper ("-")
        gops: 1800.0,
    }
}

/// The paper's own published rows (for calibration cross-checks and
/// headline-ratio tests — NOT what our benches report as "measured").
pub mod paper_rows {
    use super::PerfPoint;

    pub fn gpu_v100s() -> PerfPoint {
        PerfPoint {
            system: "GPU (paper)".into(),
            platform: "Tesla V100S".into(),
            bitwidth: "FP32".into(),
            freq_mhz: 1245.0,
            power_w: 51.0,
            latency_ms: 40.1,
            gops: 54.86,
        }
    }

    pub fn edge_moe() -> PerfPoint {
        PerfPoint {
            system: "Edge-MoE (paper)".into(),
            platform: "ZCU102".into(),
            bitwidth: "W16A32".into(),
            freq_mhz: 300.0,
            power_w: 14.54,
            latency_ms: 34.64,
            gops: 72.15,
        }
    }

    pub fn ubimoe_zcu102() -> PerfPoint {
        PerfPoint {
            system: "UbiMoE (paper)".into(),
            platform: "ZCU102".into(),
            bitwidth: "W16A32".into(),
            freq_mhz: 300.0,
            power_w: 11.50,
            latency_ms: 25.76,
            gops: 97.04,
        }
    }

    pub fn ubimoe_u280() -> PerfPoint {
        PerfPoint {
            system: "UbiMoE (paper)".into(),
            platform: "U280".into(),
            bitwidth: "W16A32".into(),
            freq_mhz: 200.0,
            power_w: 32.49,
            latency_ms: 10.33,
            gops: 242.01,
        }
    }

    pub fn ubimoe_e() -> PerfPoint {
        PerfPoint {
            system: "UbiMoE-E (paper)".into(),
            platform: "ZCU102".into(),
            bitwidth: "INT16".into(),
            freq_mhz: 300.0,
            power_w: 9.94,
            latency_ms: 8.20,
            gops: 304.84,
        }
    }

    pub fn ubimoe_c() -> PerfPoint {
        PerfPoint {
            system: "UbiMoE-C (paper)".into(),
            platform: "U280".into(),
            bitwidth: "INT16".into(),
            freq_mhz: 250.0,
            power_w: 31.36,
            latency_ms: 11.66,
            gops: 789.72,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_internal_consistency() {
        // GOPS × latency must give the same total-GOP for all M3ViT
        // rows within rounding — a sanity check that we transcribed the
        // table correctly.
        let rows =
            [paper_rows::gpu_v100s(), paper_rows::edge_moe(), paper_rows::ubimoe_zcu102()];
        let gop: Vec<f64> = rows.iter().map(|r| r.gops * r.latency_ms / 1e3).collect();
        for g in &gop {
            assert!((g - 2.35).abs() < 0.25, "implied GOP {g}");
        }
    }

    #[test]
    fn paper_headline_ratios() {
        // §I claims: 1.34×/3.35× throughput and 1.75×/1.54× efficiency.
        // Note the paper's own Table II is slightly inconsistent: it
        // prints 4.83 GOPS/W for Edge-MoE while 72.15/14.54 = 4.96, so
        // the efficiency ratios only reproduce to ~5%.
        let e = paper_rows::edge_moe();
        let z = paper_rows::ubimoe_zcu102();
        let u = paper_rows::ubimoe_u280();
        assert!((z.speedup_over(&e) - 1.34).abs() < 0.02);
        assert!((u.speedup_over(&e) - 3.35).abs() < 0.02);
        assert!((z.efficiency_gain_over(&e) - 1.75).abs() < 0.09);
        assert!((u.efficiency_gain_over(&e) - 1.54).abs() < 0.09);
    }

    #[test]
    fn heatvit_efficiency_as_published() {
        let h = heatvit();
        assert!((h.gops_per_w() - 20.62).abs() < 0.05);
        let t = tecs23();
        assert!((t.gops_per_w() - 23.32).abs() < 0.05);
    }
}
